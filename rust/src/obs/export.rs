//! Hand-rolled exporters over a [`MetricsSnapshot`]: Prometheus
//! text-exposition, a JSON snapshot, and a pandas-ready CSV dump of
//! rolling [`WindowSnapshot`]s. Zero dependencies; the escaping rules
//! are pinned by round-trip tests below so a scraper never sees a
//! malformed line no matter what ends up in a label value.
//!
//! The CLI surface is `repro telemetry --metrics-out PATH
//! [--metrics-every S]`: the file extension picks the encoder
//! (`.json` → [`json_snapshot`], `.csv` → [`windows_csv`], anything
//! else → [`prometheus_text`]), and the library surface is
//! `ServiceHandle::metrics()` plus these three functions.

use super::metrics::{HistogramSnapshot, MetricDesc, Histogram, MetricsSnapshot};
use crate::telemetry::WindowSnapshot;

/// Escape a Prometheus label value: backslash, double quote, and
/// newline, per the text-exposition format.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Escape a Prometheus HELP text: backslash and newline only (quotes
/// are legal there).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render a `{k="v",...}` label block; `extra` appends one more pair
/// (the histogram `le` bound). Empty label sets render as nothing.
fn label_block(labels: &[(String, String)], extra: Option<(&str, String)>) -> String {
    let mut pairs: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some((k, v)) = extra {
        pairs.push(format!("{k}=\"{}\"", escape_label(&v)));
    }
    if pairs.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", pairs.join(","))
    }
}

fn header(out: &mut String, last: &mut String, d: &MetricDesc, kind: &str) {
    if *last != d.name {
        out.push_str(&format!("# HELP {} {}\n# TYPE {} {}\n", d.name, escape_help(&d.help), d.name, kind));
        *last = d.name.clone();
    }
}

/// Encode a snapshot in the Prometheus text-exposition format:
/// `# HELP`/`# TYPE` once per metric name, one line per series,
/// histograms as cumulative `_bucket{le=...}` lines (empty buckets
/// elided) plus `_sum`/`_count`.
pub fn prometheus_text(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut last = String::new();
    for (d, v) in &snap.counters {
        header(&mut out, &mut last, d, "counter");
        out.push_str(&format!("{}{} {v}\n", d.name, label_block(&d.labels, None)));
    }
    for (d, v) in &snap.gauges {
        header(&mut out, &mut last, d, "gauge");
        out.push_str(&format!("{}{} {v}\n", d.name, label_block(&d.labels, None)));
    }
    for (d, h) in &snap.histograms {
        header(&mut out, &mut last, d, "histogram");
        let mut cum = 0u64;
        for (b, n) in h.buckets.iter().enumerate() {
            if *n == 0 {
                continue;
            }
            cum += n;
            let le = Histogram::upper_bound(b).to_string();
            out.push_str(&format!(
                "{}_bucket{} {cum}\n",
                d.name,
                label_block(&d.labels, Some(("le", le)))
            ));
        }
        out.push_str(&format!(
            "{}_bucket{} {}\n",
            d.name,
            label_block(&d.labels, Some(("le", "+Inf".to_string()))),
            h.count()
        ));
        out.push_str(&format!("{}_sum{} {}\n", d.name, label_block(&d.labels, None), h.sum));
        out.push_str(&format!("{}_count{} {}\n", d.name, label_block(&d.labels, None), h.count()));
    }
    out
}

/// Escape a JSON string body: quote, backslash, and all control
/// characters (named escapes where JSON has them, `\u00XX` otherwise).
fn escape_json(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            _ => out.push(c),
        }
    }
    out
}

fn json_labels(labels: &[(String, String)]) -> String {
    let pairs: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("\"{}\":\"{}\"", escape_json(k), escape_json(v)))
        .collect();
    format!("{{{}}}", pairs.join(","))
}

fn json_hist(h: &HistogramSnapshot) -> String {
    let buckets: Vec<String> = h
        .buckets
        .iter()
        .enumerate()
        .filter(|(_, n)| **n > 0)
        .map(|(b, n)| format!("[{},{n}]", Histogram::upper_bound(b)))
        .collect();
    format!("{{\"count\":{},\"sum\":{},\"buckets\":[{}]}}", h.count(), h.sum, buckets.join(","))
}

/// Encode a snapshot as a single JSON document
/// (`telemetry_metrics/v1`): three arrays of `{name, labels, value}`
/// series, histograms with their non-empty `[upper_bound, count]`
/// bucket pairs.
pub fn json_snapshot(snap: &MetricsSnapshot) -> String {
    let mut out = String::from("{\n  \"schema\": \"telemetry_metrics/v1\",\n  \"counters\": [");
    let series = |d: &MetricDesc, val: String| {
        format!(
            "\n    {{\"name\":\"{}\",\"labels\":{},\"value\":{val}}}",
            escape_json(&d.name),
            json_labels(&d.labels)
        )
    };
    let join = |items: Vec<String>| items.join(",");
    out.push_str(&join(snap.counters.iter().map(|(d, v)| series(d, v.to_string())).collect()));
    out.push_str("\n  ],\n  \"gauges\": [");
    out.push_str(&join(snap.gauges.iter().map(|(d, v)| series(d, v.to_string())).collect()));
    out.push_str("\n  ],\n  \"histograms\": [");
    out.push_str(&join(snap.histograms.iter().map(|(d, h)| series(d, json_hist(h))).collect()));
    out.push_str("\n  ]\n}\n");
    out
}

/// Dump rolling window snapshots as a pandas-ready CSV: one row per
/// observation window, full-precision floats (`read_csv` round-trips
/// them), percentage errors precomputed.
pub fn windows_csv(wins: &[WindowSnapshot]) -> String {
    let mut out = String::from(
        "window,t0_s,t1_s,truth_j,naive_j,corrected_j,bound_j,naive_pct_err,corrected_pct_err\n",
    );
    for w in wins {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{}\n",
            w.index,
            w.t0,
            w.t1,
            w.truth_j,
            w.naive_j,
            w.corrected_j,
            w.bound_j,
            w.naive_pct(),
            w.corrected_pct()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::MetricsRegistry;

    fn labelled_snapshot() -> MetricsSnapshot {
        let reg = MetricsRegistry::default();
        let c = reg.counter(
            "demo_total",
            "demo help",
            &[("path", "C:\\tmp\n\"x\"".to_string())],
        );
        c.add(3);
        let g = reg.gauge("demo_depth", "a depth", &[]);
        g.set(-2);
        let h = reg.histogram("demo_ns", "a latency", &[("shard", "0".to_string())]);
        h.record(1);
        h.record(3);
        h.record(3);
        h.record(900);
        reg.snapshot()
    }

    /// The exact text-exposition bytes are pinned, escaping included:
    /// backslash → `\\`, quote → `\"`, newline → `\n`, histograms
    /// cumulative with `+Inf`.
    #[test]
    fn prometheus_encoding_is_pinned() {
        let text = prometheus_text(&labelled_snapshot());
        let want = "\
# HELP demo_total demo help
# TYPE demo_total counter
demo_total{path=\"C:\\\\tmp\\n\\\"x\\\"\"} 3
# HELP demo_depth a depth
# TYPE demo_depth gauge
demo_depth -2
# HELP demo_ns a latency
# TYPE demo_ns histogram
demo_ns_bucket{shard=\"0\",le=\"2\"} 1
demo_ns_bucket{shard=\"0\",le=\"4\"} 3
demo_ns_bucket{shard=\"0\",le=\"1024\"} 4
demo_ns_bucket{shard=\"0\",le=\"+Inf\"} 4
demo_ns_sum{shard=\"0\"} 907
demo_ns_count{shard=\"0\"} 4
";
        assert_eq!(text, want);
    }

    /// Un-escaping the escaped label value recovers the original string
    /// — the "round-trip" guarantee a scraper relies on.
    #[test]
    fn label_escaping_round_trips() {
        let nasty = "a\\b \"quoted\"\nnext \\n literal \\\" too";
        let escaped = escape_label(nasty);
        assert!(!escaped.contains('\n'), "escaped value is single-line");
        // the text-format unescape: \\ -> \, \" -> ", \n -> newline
        let mut back = String::new();
        let mut it = escaped.chars();
        while let Some(c) = it.next() {
            if c != '\\' {
                back.push(c);
                continue;
            }
            match it.next() {
                Some('\\') => back.push('\\'),
                Some('"') => back.push('"'),
                Some('n') => back.push('\n'),
                other => panic!("unknown escape \\{other:?}"),
            }
        }
        assert_eq!(back, nasty);
    }

    /// JSON escaping is pinned and round-trips through a standard JSON
    /// string unescape (quotes, backslashes, control characters).
    #[test]
    fn json_escaping_round_trips() {
        let nasty = "say \"hi\"\\\n\tctrl:\u{1}";
        let escaped = escape_json(nasty);
        assert_eq!(escaped, "say \\\"hi\\\"\\\\\\n\\tctrl:\\u0001");
        let mut back = String::new();
        let mut it = escaped.chars();
        while let Some(c) = it.next() {
            if c != '\\' {
                back.push(c);
                continue;
            }
            match it.next() {
                Some('"') => back.push('"'),
                Some('\\') => back.push('\\'),
                Some('n') => back.push('\n'),
                Some('r') => back.push('\r'),
                Some('t') => back.push('\t'),
                Some('u') => {
                    let hex: String = (0..4).map(|_| it.next().unwrap()).collect();
                    back.push(char::from_u32(u32::from_str_radix(&hex, 16).unwrap()).unwrap());
                }
                other => panic!("unknown escape \\{other:?}"),
            }
        }
        assert_eq!(back, nasty);
    }

    #[test]
    fn json_document_shape_is_pinned() {
        let doc = json_snapshot(&labelled_snapshot());
        assert!(doc.starts_with("{\n  \"schema\": \"telemetry_metrics/v1\""));
        assert!(doc.contains(
            "{\"name\":\"demo_total\",\"labels\":{\"path\":\"C:\\\\tmp\\n\\\"x\\\"\"},\"value\":3}"
        ));
        assert!(doc.contains("{\"name\":\"demo_depth\",\"labels\":{},\"value\":-2}"));
        assert!(doc.contains("\"value\":{\"count\":4,\"sum\":907,\"buckets\":[[2,1],[4,2],[1024,1]]}"));
        assert!(doc.trim_end().ends_with('}'));
    }

    #[test]
    fn windows_csv_is_pandas_ready() {
        // energies chosen so the percentage errors are exact in binary
        // (−25 %, −12.5 %) and the pinned strings can't drift by an ulp
        let wins = [
            WindowSnapshot {
                index: 0,
                t0: 0.0,
                t1: 40.0,
                naive_j: 750.0,
                corrected_j: 875.0,
                bound_j: 25.0,
                truth_j: 1000.0,
            },
            WindowSnapshot {
                index: 1,
                t0: 40.0,
                t1: 80.0,
                naive_j: 375.0,
                corrected_j: 437.5,
                bound_j: 12.5,
                truth_j: 500.0,
            },
        ];
        let csv = windows_csv(&wins);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(
            lines[0],
            "window,t0_s,t1_s,truth_j,naive_j,corrected_j,bound_j,naive_pct_err,corrected_pct_err"
        );
        assert_eq!(lines[1], "0,0,40,1000,750,875,25,-25,-12.5");
        assert_eq!(lines[2], "1,40,80,500,375,437.5,12.5,-25,-12.5");
        // every row has the header's arity — what read_csv needs
        for l in &lines[1..] {
            assert_eq!(l.split(',').count(), 9);
        }
    }
}
