//! Lock-free metric primitives and the process-wide registry.
//!
//! Three instrument kinds, all safe to sample from any thread without a
//! lock:
//!
//! * [`Counter`] — monotone `AtomicU64`; one relaxed `fetch_add` per
//!   sample.
//! * [`Gauge`] — signed `AtomicI64` level (queue depths, backlog lengths,
//!   byte sizes, millisecond marks); relaxed `store`/`fetch_add`.
//! * [`Histogram`] — fixed array of log2 buckets plus a running sum; a
//!   sample is two relaxed `fetch_add`s (bucket + sum), no allocation,
//!   no resizing, no lock.
//!
//! Ordering: every operation is `Ordering::Relaxed` on purpose. Metrics
//! are *observational* — they never gate control flow, so they need
//! atomicity (no torn counts) but not inter-thread ordering. A reader
//! may observe counters from an in-flight batch slightly out of step
//! with each other; totals are exact once the writers quiesce (thread
//! join is the synchronisation point, exactly as for
//! `IngestStats`). This is what keeps the hot-path cost to one relaxed
//! atomic op per reading.
//!
//! [`MetricsRegistry`] is the cold-path directory: registration takes a
//! `Mutex` once per metric at service launch, hands back an `Arc` to the
//! instrument, and never touches the hot path again. [`snapshot`]
//! ([`MetricsRegistry::snapshot`]) produces a [`MetricsSnapshot`] — a
//! plain, sorted value type the [`super::export`] encoders and the
//! [`super::console`] dashboard render without holding any lock.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotone event counter. One relaxed atomic add per sample.
///
/// Cache-line-aligned so two instruments can never share a line: the
/// per-shard counters are hammered from different producer/consumer
/// threads, and without the alignment the allocator is free to pack
/// several 8-byte atomics into one 64-byte line, turning independent
/// shards' relaxed adds into cross-core cache-line ping-pong (false
/// sharing) that grows with the shard count.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct Counter(AtomicU64);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current total.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Signed instantaneous level (queue depth, backlog length, bytes,
/// millisecond marks). Relaxed atomics throughout.
///
/// Cache-line-aligned for the same false-sharing hygiene as [`Counter`]:
/// per-shard gauges are written by different threads and must not share
/// a line.
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Overwrite the level.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Shift the level by `d` and return the post-shift value (so a
    /// producer can feed a high-water mark without a second load).
    pub fn add(&self, d: i64) -> i64 {
        self.0.fetch_add(d, Ordering::Relaxed) + d
    }

    /// Raise the level to `v` if `v` is higher (high-water marks).
    pub fn fetch_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets a [`Histogram`] holds. Bucket `b` covers
/// `[2^b, 2^(b+1))`, so 44 buckets span 1 ns to ~4.8 hours when samples
/// are nanoseconds — wide enough that no latency this service can
/// produce falls off the end.
pub const HISTOGRAM_BUCKETS: usize = 44;

/// Fixed-bucket log2 histogram: bucket `b` counts samples in
/// `[2^b, 2^(b+1))` (samples of 0 land in bucket 0). Recording is two
/// relaxed atomic adds — bucket count and running sum — with no lock,
/// allocation, or resize ever.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    sum: AtomicU64,
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }

    /// The bucket index `v` falls into: `floor(log2(v))`, clamped to the
    /// top bucket; 0 and 1 land in bucket 0.
    pub fn bucket_of(v: u64) -> usize {
        if v <= 1 {
            0
        } else {
            ((63 - v.leading_zeros()) as usize).min(HISTOGRAM_BUCKETS - 1)
        }
    }

    /// Exclusive upper bound of bucket `b` (`2^(b+1)`); the top bucket
    /// is unbounded in spirit but reports its nominal edge.
    pub fn upper_bound(b: usize) -> u64 {
        1u64 << (b as u32 + 1).min(63)
    }

    /// Record one sample.
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Copy the current counts out into a plain value.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Name, help text, and label set identifying one metric series.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct MetricDesc {
    /// Metric name (`snake_case`, Prometheus-compatible).
    pub name: String,
    /// One-line human description (the Prometheus `# HELP` line).
    pub help: String,
    /// Label key/value pairs distinguishing series of the same name.
    pub labels: Vec<(String, String)>,
}

/// Point-in-time copy of one [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts ([`HISTOGRAM_BUCKETS`] entries; bucket
    /// `b` covers `[2^b, 2^(b+1))`).
    pub buckets: Vec<u64>,
    /// Sum of all recorded sample values.
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }
}

enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

struct Registration {
    desc: MetricDesc,
    instrument: Instrument,
}

/// Cold-path directory of every registered instrument. Registration
/// locks a `Mutex` once (at service launch); sampling goes through the
/// returned `Arc` and never sees the registry again.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Vec<Registration>>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.inner.lock().map(|v| v.len()).unwrap_or(0);
        write!(fm, "MetricsRegistry({n} series)")
    }
}

impl MetricsRegistry {
    fn desc(name: &str, help: &str, labels: &[(&str, String)]) -> MetricDesc {
        MetricDesc {
            name: name.to_string(),
            help: help.to_string(),
            labels: labels.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        }
    }

    /// Register (and return) a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, String)]) -> Arc<Counter> {
        let c = Arc::new(Counter::default());
        let mut reg = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        reg.push(Registration {
            desc: Self::desc(name, help, labels),
            instrument: Instrument::Counter(Arc::clone(&c)),
        });
        c
    }

    /// Register (and return) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, String)]) -> Arc<Gauge> {
        let g = Arc::new(Gauge::default());
        let mut reg = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        reg.push(Registration {
            desc: Self::desc(name, help, labels),
            instrument: Instrument::Gauge(Arc::clone(&g)),
        });
        g
    }

    /// Register (and return) a histogram series.
    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, String)]) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        let mut reg = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        reg.push(Registration {
            desc: Self::desc(name, help, labels),
            instrument: Instrument::Histogram(Arc::clone(&h)),
        });
        h
    }

    /// Copy every series into a sorted, lock-free value the exporters
    /// and the console render from. Sorted by (name, labels) so output
    /// is deterministic regardless of registration order.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let reg = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let mut snap = MetricsSnapshot::default();
        for r in reg.iter() {
            match &r.instrument {
                Instrument::Counter(c) => snap.counters.push((r.desc.clone(), c.get())),
                Instrument::Gauge(g) => snap.gauges.push((r.desc.clone(), g.get())),
                Instrument::Histogram(h) => snap.histograms.push((r.desc.clone(), h.snapshot())),
            }
        }
        snap.counters.sort_by(|a, b| a.0.cmp(&b.0));
        snap.gauges.sort_by(|a, b| a.0.cmp(&b.0));
        snap.histograms.sort_by(|a, b| a.0.cmp(&b.0));
        snap
    }
}

/// Point-in-time copy of every registered series, sorted by
/// (name, labels). Plain data: clone it, ship it across threads, render
/// it — no locks, no `Arc`s back into the live service.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    /// Counter series and their totals.
    pub counters: Vec<(MetricDesc, u64)>,
    /// Gauge series and their levels.
    pub gauges: Vec<(MetricDesc, i64)>,
    /// Histogram series and their bucket counts.
    pub histograms: Vec<(MetricDesc, HistogramSnapshot)>,
}

impl MetricsSnapshot {
    /// Sum of every counter series named `name` (labelled series of one
    /// name add up — e.g. total readings across shards).
    pub fn counter_total(&self, name: &str) -> u64 {
        self.counters.iter().filter(|(d, _)| d.name == name).map(|(_, v)| v).sum()
    }

    /// Sum of every gauge series named `name`, or `None` if no such
    /// series exists.
    pub fn gauge_total(&self, name: &str) -> Option<i64> {
        let mut hit = false;
        let mut total = 0i64;
        for (d, v) in &self.gauges {
            if d.name == name {
                hit = true;
                total += v;
            }
        }
        hit.then_some(total)
    }
}

/// Per-accounting-shard instruments. Producer workers drive the
/// counters and queue gauges (so they see mid-batch work the consumer
/// hasn't drained yet); the consumer drives the deferred-readings gauge
/// and decrements the queue depth as it drains.
#[derive(Debug)]
pub struct ShardMetrics {
    /// Node streams started on this shard.
    pub nodes: Arc<Counter>,
    /// Reading batches pushed to this shard's queue.
    pub batches: Arc<Counter>,
    /// Power readings pushed to this shard's queue.
    pub readings: Arc<Counter>,
    /// Messages currently in flight (queued or being consumed).
    pub queue_depth: Arc<Gauge>,
    /// Highest queue depth ever observed (backpressure indicator).
    pub queue_high_water: Arc<Gauge>,
    /// Readings deferred in accountants awaiting epoch identification.
    pub deferred_readings: Arc<Gauge>,
    /// Producer batch-push latency (blocking send), nanoseconds.
    pub push_wait_ns: Arc<Histogram>,
}

/// Every instrument the telemetry service exposes, pre-registered at
/// launch so the hot path never touches the registry. Held in the
/// service's shared core; [`crate::telemetry::ServiceHandle::metrics`]
/// snapshots it and `repro watch` renders it live.
///
/// `enabled == false` (from `TelemetryConfig::metrics`) turns the
/// *hot-path* sampling off — the instruments still exist and read as
/// zero/idle — which is what the instrumentation-overhead bench A/Bs.
/// Cold-path updates (event backlog, windows, checkpoints) are always
/// on: they are one atomic op per *event*, not per reading.
#[derive(Debug)]
pub struct ServiceMetrics {
    /// Whether hot-path (per-reading / per-batch) sampling is active.
    pub enabled: bool,
    /// The directory behind [`ServiceMetrics::snapshot`].
    pub registry: MetricsRegistry,
    /// Per-shard instruments, indexed by shard id.
    pub shards: Vec<ShardMetrics>,
    /// Adaptive/commanded probe replays observed at the producers.
    pub recalibrations: Arc<Counter>,
    /// Drift-monitor suspicions raised at the producers.
    pub drift_suspected: Arc<Counter>,
    /// Service events emitted (retained + trimmed).
    pub events_emitted: Arc<Counter>,
    /// Events evicted from the bounded backlog.
    pub events_trimmed: Arc<Counter>,
    /// Events currently retained in the backlog.
    pub event_backlog_len: Arc<Gauge>,
    /// Observation windows closed (final) so far.
    pub windows_closed: Arc<Gauge>,
    /// Observation windows covered by a published checkpoint file.
    pub windows_published: Arc<Gauge>,
    /// Query folds served straight from an unchanged shard's cache
    /// (no shard state lock taken) — see the service's snapshot cache.
    pub snapshot_cache_hits: Arc<Counter>,
    /// Query folds that had to re-extract a shard whose version moved.
    pub snapshot_cache_refolds: Arc<Counter>,
    /// Checkpoint files published.
    pub checkpoints_written: Arc<Counter>,
    /// Checkpoint encode+write+rename duration, nanoseconds.
    pub checkpoint_write_ns: Arc<Histogram>,
    /// Byte size of the most recent checkpoint file.
    pub checkpoint_bytes: Arc<Gauge>,
    /// Service uptime at the most recent checkpoint write, milliseconds
    /// (−1 until the first write).
    pub checkpoint_last_write_ms: Arc<Gauge>,
    uptime_ms: Arc<Gauge>,
    started: Instant,
}

impl ServiceMetrics {
    /// Register the full instrument set for an `n_shards`-shard service.
    pub fn new(n_shards: usize, enabled: bool) -> ServiceMetrics {
        let reg = MetricsRegistry::default();
        let shards = (0..n_shards.max(1))
            .map(|i| {
                let l = [("shard", i.to_string())];
                ShardMetrics {
                    nodes: reg.counter(
                        "telemetry_shard_nodes_total",
                        "Node streams started, by owning accounting shard.",
                        &l,
                    ),
                    batches: reg.counter(
                        "telemetry_shard_batches_total",
                        "Reading batches pushed to the shard queue.",
                        &l,
                    ),
                    readings: reg.counter(
                        "telemetry_shard_readings_total",
                        "Power readings pushed to the shard queue.",
                        &l,
                    ),
                    queue_depth: reg.gauge(
                        "telemetry_shard_queue_depth",
                        "Messages currently in flight on the shard queue.",
                        &l,
                    ),
                    queue_high_water: reg.gauge(
                        "telemetry_shard_queue_high_water",
                        "Highest observed shard queue depth.",
                        &l,
                    ),
                    deferred_readings: reg.gauge(
                        "telemetry_shard_deferred_readings",
                        "Readings deferred awaiting epoch identification.",
                        &l,
                    ),
                    push_wait_ns: reg.histogram(
                        "telemetry_shard_push_wait_ns",
                        "Producer batch-push latency (blocking send), nanoseconds.",
                        &l,
                    ),
                }
            })
            .collect();
        let m = ServiceMetrics {
            enabled,
            shards,
            recalibrations: reg.counter(
                "telemetry_recalibrations_total",
                "Adaptive/commanded probe replays.",
                &[],
            ),
            drift_suspected: reg.counter(
                "telemetry_drift_suspected_total",
                "Drift-monitor suspicions raised.",
                &[],
            ),
            events_emitted: reg.counter("telemetry_events_total", "Service events emitted.", &[]),
            events_trimmed: reg.counter(
                "telemetry_events_trimmed_total",
                "Events evicted from the bounded backlog.",
                &[],
            ),
            event_backlog_len: reg.gauge(
                "telemetry_event_backlog_len",
                "Events currently retained in the backlog.",
                &[],
            ),
            windows_closed: reg.gauge(
                "telemetry_windows_closed",
                "Observation windows closed (final).",
                &[],
            ),
            windows_published: reg.gauge(
                "telemetry_windows_published",
                "Observation windows covered by a published checkpoint.",
                &[],
            ),
            snapshot_cache_hits: reg.counter(
                "telemetry_snapshot_cache_hits_total",
                "Shard query folds served from an unchanged shard's cache.",
                &[],
            ),
            snapshot_cache_refolds: reg.counter(
                "telemetry_snapshot_cache_refolds_total",
                "Shard query folds that re-extracted a changed shard.",
                &[],
            ),
            checkpoints_written: reg.counter(
                "telemetry_checkpoints_total",
                "Checkpoint files published.",
                &[],
            ),
            checkpoint_write_ns: reg.histogram(
                "telemetry_checkpoint_write_ns",
                "Checkpoint encode+write+rename duration, nanoseconds.",
                &[],
            ),
            checkpoint_bytes: reg.gauge(
                "telemetry_checkpoint_bytes",
                "Size of the most recent checkpoint file, bytes.",
                &[],
            ),
            checkpoint_last_write_ms: reg.gauge(
                "telemetry_checkpoint_last_write_ms",
                "Uptime at the most recent checkpoint write, ms (-1 before any).",
                &[],
            ),
            uptime_ms: reg.gauge("telemetry_uptime_ms", "Service uptime, milliseconds.", &[]),
            registry: reg,
            started: Instant::now(),
        };
        m.checkpoint_last_write_ms.set(-1);
        m
    }

    /// Milliseconds since the service launched.
    pub fn elapsed_ms(&self) -> i64 {
        self.started.elapsed().as_millis() as i64
    }

    /// Milliseconds since the last checkpoint write, or −1 if none has
    /// been written.
    pub fn checkpoint_age_ms(&self) -> i64 {
        let last = self.checkpoint_last_write_ms.get();
        if last < 0 {
            -1
        } else {
            (self.elapsed_ms() - last).max(0)
        }
    }

    /// Refresh the derived gauges (uptime) and snapshot every series.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.uptime_ms.set(self.elapsed_ms());
        self.registry.snapshot()
    }
}

/// Instruments for the network plane (`repro serve`). Registered into
/// the *service's* registry so connection telemetry flows through the
/// same exporters (`--metrics-out`, watch console) as the accounting
/// instruments, with no extra plumbing.
#[derive(Debug, Clone)]
pub struct NetMetrics {
    /// Clients currently connected.
    pub clients_connected: Arc<Gauge>,
    /// Frames accepted from clients.
    pub frames_in: Arc<Counter>,
    /// Frames written to clients.
    pub frames_out: Arc<Counter>,
    /// Bytes accepted from clients (framing included).
    pub bytes_in: Arc<Counter>,
    /// Bytes written to clients (framing included).
    pub bytes_out: Arc<Counter>,
    /// Events subscribers missed to backlog trimming (sum of `Lagged`
    /// gap sizes observed on the wire).
    pub subscribe_lagged: Arc<Counter>,
    /// Frames rejected at the framing layer (bad magic/version/length/
    /// checksum, truncation). Each costs the sender its connection.
    pub frames_rejected: Arc<Counter>,
}

impl NetMetrics {
    /// Register the network instrument set into `reg`.
    pub fn register(reg: &MetricsRegistry) -> NetMetrics {
        NetMetrics {
            clients_connected: reg.gauge(
                "telemetry_net_clients_connected",
                "Network clients currently connected.",
                &[],
            ),
            frames_in: reg.counter(
                "telemetry_net_frames_in_total",
                "Wire frames accepted from clients.",
                &[],
            ),
            frames_out: reg.counter(
                "telemetry_net_frames_out_total",
                "Wire frames written to clients.",
                &[],
            ),
            bytes_in: reg.counter(
                "telemetry_net_bytes_in_total",
                "Bytes accepted from clients, framing included.",
                &[],
            ),
            bytes_out: reg.counter(
                "telemetry_net_bytes_out_total",
                "Bytes written to clients, framing included.",
                &[],
            ),
            subscribe_lagged: reg.counter(
                "telemetry_net_subscribe_lagged_total",
                "Events wire subscribers missed to backlog trimming.",
                &[],
            ),
            frames_rejected: reg.counter(
                "telemetry_net_frames_rejected_total",
                "Frames rejected at the framing layer (connection dropped).",
                &[],
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// False-sharing hygiene (ISSUE 8): every instrument occupies its own
    /// cache line, so per-shard counters hammered from different threads
    /// can never ping-pong one line between cores.
    #[test]
    fn instruments_are_cache_line_aligned() {
        assert_eq!(std::mem::align_of::<Counter>(), 64);
        assert_eq!(std::mem::align_of::<Gauge>(), 64);
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);

        let g = Gauge::default();
        g.set(7);
        assert_eq!(g.add(-3), 4);
        g.fetch_max(10);
        assert_eq!(g.get(), 10);
        g.fetch_max(2);
        assert_eq!(g.get(), 10, "fetch_max never lowers");
    }

    #[test]
    fn histogram_bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(4), 2);
        assert_eq!(Histogram::bucket_of(1023), 9);
        assert_eq!(Histogram::bucket_of(1024), 10);
        assert_eq!(Histogram::bucket_of(u64::MAX), HISTOGRAM_BUCKETS - 1);
        assert_eq!(Histogram::upper_bound(0), 2);
        assert_eq!(Histogram::upper_bound(9), 1024);

        let h = Histogram::new();
        h.record(0);
        h.record(1);
        h.record(1000);
        let s = h.snapshot();
        assert_eq!(s.count(), 3);
        assert_eq!(s.sum, 1001);
        assert_eq!(s.buckets[0], 2);
        assert_eq!(s.buckets[9], 1);
    }

    #[test]
    fn registry_snapshot_is_sorted_and_summable() {
        let reg = MetricsRegistry::default();
        // register out of order on purpose
        let b = reg.counter("zzz_total", "last by name", &[]);
        let a1 = reg.counter("aaa_total", "first by name", &[("shard", "1".to_string())]);
        let a0 = reg.counter("aaa_total", "first by name", &[("shard", "0".to_string())]);
        let g = reg.gauge("depth", "a gauge", &[]);
        a0.add(2);
        a1.add(3);
        b.inc();
        g.set(-5);

        let snap = reg.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(d, _)| d.name.as_str()).collect();
        assert_eq!(names, ["aaa_total", "aaa_total", "zzz_total"]);
        assert_eq!(snap.counters[0].0.labels[0].1, "0", "label order sorted too");
        assert_eq!(snap.counter_total("aaa_total"), 5);
        assert_eq!(snap.gauge_total("depth"), Some(-5));
        assert_eq!(snap.gauge_total("missing"), None);
    }

    #[test]
    fn counters_are_exact_under_contention() {
        let c = Arc::new(Counter::default());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 40_000);
    }

    #[test]
    fn service_metrics_register_per_shard_series() {
        let m = ServiceMetrics::new(3, true);
        assert_eq!(m.shards.len(), 3);
        m.shards[2].readings.add(9);
        assert_eq!(m.checkpoint_age_ms(), -1, "no checkpoint yet");
        let snap = m.snapshot();
        assert_eq!(snap.counter_total("telemetry_shard_readings_total"), 9);
        assert_eq!(
            snap.counters.iter().filter(|(d, _)| d.name == "telemetry_shard_readings_total").count(),
            3
        );
        assert!(snap.gauge_total("telemetry_uptime_ms").is_some());
    }
}
