//! The live operator console behind `repro watch`: renders one
//! dashboard frame from a [`TelemetrySnapshot`], a [`ConsoleMetrics`]
//! gauge capture, and the event feed a `subscribe()` stream has
//! delivered so far.
//!
//! [`ConsoleMetrics`] is a plain-data capture of exactly the instrument
//! values a frame renders, rather than a borrow of the live
//! [`ServiceMetrics`] — so the same renderer serves a local handle
//! (`ConsoleMetrics::from(handle.metrics_handle())`) and a remote
//! collector (`repro watch --connect`, which receives the capture in a
//! `Progress` response). Local and remote frames over the same state
//! are byte-identical by construction.
//!
//! Frames are plain strings. In interactive mode the CLI clears the
//! screen between frames (`--every S` cadence, minimal ANSI); with
//! `--headless --frames N` it waits for the service to drain and then
//! prints N identical frames to stdout — every field in a post-drain
//! frame is a deterministic function of the run (queue depths are zero,
//! the accounts are final, no wall-clock-derived value is rendered), so
//! CI and the integration suite can pin frames byte-for-byte.
//!
//! [`status_line`] is shared with the `repro telemetry --live-every`
//! output: both the `[live]` ticker and the watch dashboard build their
//! progress row through this one function from
//! `ServiceHandle::progress()`, which is what makes the two surfaces
//! agree bit-for-bit.

use std::collections::VecDeque;

use super::metrics::ServiceMetrics;
use crate::telemetry::{FleetEnergy, IngestStats, ServiceEvent, TelemetrySnapshot};

/// The one-line progress summary shared by `repro telemetry
/// --live-every` (prefixed `[live]`) and the watch dashboard's
/// `status` row. Same inputs → same bytes, on both surfaces.
pub fn status_line(
    stats: &IngestStats,
    n_total: usize,
    finished: usize,
    identified: usize,
    e: &FleetEnergy,
) -> String {
    format!(
        "nodes {}/{} streaming, {} finished, {} identified | {} readings | naive {:.3} kJ, corrected {:.3} kJ (±{:.3} kJ)",
        stats.nodes,
        n_total,
        finished,
        identified,
        stats.readings,
        crate::units::j_to_kj(e.naive_j),
        crate::units::j_to_kj(e.corrected_j),
        crate::units::j_to_kj(e.bound_j),
    )
}

/// Rolling digest of a `subscribe()` stream for the dashboard's event
/// pane: counts drift suspicions, probe replays, and `Lagged` gaps, and
/// keeps the most recent `cap` human-readable drift/recalibration lines.
#[derive(Debug)]
pub struct EventFeed {
    cap: usize,
    /// Drift suspicions seen on this stream.
    pub drift: u64,
    /// Probe replays (recalibrations) seen on this stream.
    pub recal: u64,
    /// Events this subscriber missed to backlog trimming.
    pub lagged: u64,
    lines: VecDeque<String>,
}

impl EventFeed {
    /// A feed retaining the latest `cap` event lines.
    pub fn new(cap: usize) -> EventFeed {
        EventFeed { cap: cap.max(1), drift: 0, recal: 0, lagged: 0, lines: VecDeque::new() }
    }

    fn push(&mut self, line: String) {
        self.lines.push_back(line);
        while self.lines.len() > self.cap {
            self.lines.pop_front();
        }
    }

    /// Fold a batch of events (e.g. `stream.try_iter()`) into the feed.
    pub fn absorb(&mut self, events: impl Iterator<Item = ServiceEvent>) {
        for ev in events {
            match ev {
                ServiceEvent::DriftSuspected { node_id, t } => {
                    self.drift += 1;
                    self.push(format!("drift suspected on node {node_id} at t={t:.1} s"));
                }
                ServiceEvent::Recalibrated { node_id, t0 } => {
                    self.recal += 1;
                    self.push(format!("probe replay on node {node_id} at t={t0:.1} s"));
                }
                ServiceEvent::Lagged { missed } => self.lagged += missed,
                _ => {}
            }
        }
    }

    /// The retained event lines, oldest first.
    pub fn lines(&self) -> impl Iterator<Item = &str> {
        self.lines.iter().map(String::as_str)
    }
}

/// The instrument values one dashboard frame renders, captured as plain
/// data. Build it [`From`] a live [`ServiceMetrics`] locally, or decode
/// it off the wire remotely — the renderer cannot tell the difference.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ConsoleMetrics {
    /// Observation windows closed (final).
    pub windows_closed: i64,
    /// Windows covered by a published checkpoint.
    pub windows_published: i64,
    /// Checkpoint files published.
    pub checkpoints_written: u64,
    /// Milliseconds since the last checkpoint write, −1 before any.
    pub checkpoint_age_ms: i64,
    /// Events currently retained in the backlog.
    pub event_backlog_len: i64,
    /// Events evicted from the bounded backlog.
    pub events_trimmed: u64,
    /// Per-shard `(queue_depth, queue_high_water, deferred_readings)`.
    pub shards: Vec<(i64, i64, i64)>,
}

impl From<&ServiceMetrics> for ConsoleMetrics {
    fn from(m: &ServiceMetrics) -> ConsoleMetrics {
        ConsoleMetrics {
            windows_closed: m.windows_closed.get(),
            windows_published: m.windows_published.get(),
            checkpoints_written: m.checkpoints_written.get(),
            checkpoint_age_ms: m.checkpoint_age_ms(),
            event_backlog_len: m.event_backlog_len.get(),
            events_trimmed: m.events_trimmed.get(),
            shards: m
                .shards
                .iter()
                .map(|s| {
                    (s.queue_depth.get(), s.queue_high_water.get(), s.deferred_readings.get())
                })
                .collect(),
        }
    }
}

/// Everything one dashboard frame renders from. The snapshot is
/// borrowed straight off a `ServiceHandle` (or reconstructed from a
/// remote checkpoint); `progress` is its `progress()` result
/// (producer-side gauges, so mid-batch work shows up).
#[derive(Debug)]
pub struct WatchFrame<'a> {
    /// 1-based frame number (shown in the title).
    pub frame_no: usize,
    /// Fleet size (denominator of the streaming count).
    pub n_total: usize,
    /// The service state being rendered.
    pub snap: &'a TelemetrySnapshot,
    /// `ServiceHandle::progress()` at render time.
    pub progress: IngestStats,
    /// Instrument capture at render time (local or off the wire).
    pub metrics: ConsoleMetrics,
    /// Digest of the events delivered so far.
    pub feed: &'a EventFeed,
    /// Emit minimal ANSI styling (bold title). Off for `--headless`.
    pub ansi: bool,
}

/// A 20-cell `[####................]` magnitude bar for a percentage
/// error, 5 % per cell, clamped at 100 %.
fn bar(pct: f64) -> String {
    let filled = ((pct.abs().min(100.0) / 5.0).round() as usize).min(20);
    let mut s = String::with_capacity(22);
    s.push('[');
    for i in 0..20 {
        s.push(if i < filled { '#' } else { '.' });
    }
    s.push(']');
    s
}

/// Render one dashboard frame: fleet energy ticker, shared status line,
/// window/checkpoint state, per-generation naive-vs-corrected error
/// bars, per-shard queue gauges, and the drift/recalibration feed.
pub fn render_frame(f: &WatchFrame<'_>) -> String {
    let mut out = String::new();
    let title = format!("== repro watch — frame {} ==", f.frame_no);
    if f.ansi {
        out.push_str(&format!("\x1b[1m{title}\x1b[0m\n"));
    } else {
        out.push_str(&title);
        out.push('\n');
    }

    // fleet energy ticker
    let e = f.snap.fleet_energy(0.0, f.snap.duration_s);
    let truth = if e.truth_j > 0.0 {
        format!("{:.3} kJ", crate::units::j_to_kj(e.truth_j))
    } else {
        "-".into()
    };
    out.push_str(&format!(
        "fleet energy    naive {:.3} kJ | corrected {:.3} kJ (±{:.3} kJ) | truth {truth}\n",
        crate::units::j_to_kj(e.naive_j),
        crate::units::j_to_kj(e.corrected_j),
        crate::units::j_to_kj(e.bound_j),
    ));

    // the shared status line (bit-for-bit the `[live]` ticker's body)
    let finished = f.snap.accounts.nodes.iter().filter(|n| n.complete).count();
    let identified = f.snap.registry.entries.len();
    out.push_str(&format!(
        "status          {}\n",
        status_line(&f.progress, f.n_total, finished, identified, &e)
    ));

    // windows and checkpoint state
    let age = match f.metrics.checkpoint_age_ms {
        a if a < 0 => "-".to_string(),
        a => format!("{:.1} s", crate::units::ms_to_s(a as f64)),
    };
    out.push_str(&format!(
        "windows         {}/{} closed, {} checkpointed | checkpoints {} | checkpoint age {age}\n",
        f.metrics.windows_closed,
        f.snap.windows().len(),
        f.metrics.windows_published,
        f.metrics.checkpoints_written,
    ));

    // per-generation naive vs corrected |error| bars (5 % per cell)
    out.push_str("per-generation  |err%| naive vs corrected (5% per cell)\n");
    let mut gens: Vec<(String, f64, f64, f64)> = Vec::new();
    for n in &f.snap.accounts.nodes {
        let name = n.generation.name();
        match gens.iter_mut().find(|g| g.0 == name) {
            Some(g) => {
                g.1 += n.truth_total_j();
                g.2 += n.naive_total_j();
                g.3 += n.corrected_total_j();
            }
            None => gens.push((
                name.to_string(),
                n.truth_total_j(),
                n.naive_total_j(),
                n.corrected_total_j(),
            )),
        }
    }
    for (name, truth, naive, corrected) in &gens {
        if *truth > 0.0 {
            let np = 100.0 * (naive - truth) / truth;
            let cp = 100.0 * (corrected - truth) / truth;
            out.push_str(&format!(
                "  {name:<12} naive {np:>+8.2} {} corrected {cp:>+8.2} {}\n",
                bar(np),
                bar(cp)
            ));
        } else {
            out.push_str(&format!("  {name:<12} no truth reference (replayed log)\n"));
        }
    }
    if gens.is_empty() {
        out.push_str("  (no accounts yet)\n");
    }

    // per-shard queue gauges
    for (i, &(depth, high_water, deferred)) in f.metrics.shards.iter().enumerate() {
        out.push_str(&format!(
            "shards          shard {i}: queue {depth} (high-water {high_water}) | deferred {deferred}\n",
        ));
    }

    // event feed
    out.push_str(&format!(
        "events          {} drift suspected, {} recalibrated | backlog {} ({} trimmed, {} missed)\n",
        f.feed.drift,
        f.feed.recal,
        f.metrics.event_backlog_len,
        f.metrics.events_trimmed,
        f.feed.lagged,
    ));
    for l in f.feed.lines() {
        out.push_str(&format!("  {l}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn energy() -> FleetEnergy {
        FleetEnergy {
            t0: 0.0,
            t1: 40.0,
            naive_j: 750.0,
            corrected_j: 875.0,
            bound_j: 25.0,
            truth_j: 1000.0,
        }
    }

    /// The exact status-line bytes are pinned — this is the contract
    /// that keeps `[live]` and `repro watch` identical.
    #[test]
    fn status_line_is_pinned() {
        let stats = IngestStats { nodes: 3, batches: 7, readings: 1234, ..Default::default() };
        assert_eq!(
            status_line(&stats, 4, 2, 3, &energy()),
            "nodes 3/4 streaming, 2 finished, 3 identified | 1234 readings | \
             naive 0.750 kJ, corrected 0.875 kJ (±0.025 kJ)"
        );
    }

    #[test]
    fn event_feed_counts_and_caps() {
        let mut feed = EventFeed::new(2);
        feed.absorb(
            [
                ServiceEvent::DriftSuspected { node_id: 1, t: 41.25 },
                ServiceEvent::Recalibrated { node_id: 1, t0: 43.0 },
                ServiceEvent::DriftSuspected { node_id: 2, t: 50.0 },
                ServiceEvent::Lagged { missed: 5 },
                ServiceEvent::ServiceComplete,
            ]
            .into_iter(),
        );
        assert_eq!((feed.drift, feed.recal, feed.lagged), (2, 1, 5));
        let lines: Vec<&str> = feed.lines().collect();
        assert_eq!(
            lines,
            ["probe replay on node 1 at t=43.0 s", "drift suspected on node 2 at t=50.0 s"],
            "cap 2 keeps only the newest lines"
        );
    }

    #[test]
    fn bars_clamp_and_scale() {
        assert_eq!(bar(0.0), "[....................]");
        assert_eq!(bar(-50.0), "[##########..........]");
        assert_eq!(bar(1e9), "[####################]");
    }
}
