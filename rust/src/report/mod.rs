//! Table/CSV rendering of experiment outputs.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let _ = writeln!(out, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    /// Write as CSV (RFC 4180 quoting: commas, quotes, and line breaks
    /// all force the cell into quotes — an unquoted newline would corrupt
    /// the row structure).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') || c.contains('\r') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(s, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, s)
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["hello, world".into()]);
        let dir = std::env::temp_dir().join("gpupower_test_csv");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"hello, world\""));
    }

    #[test]
    fn csv_quotes_line_breaks() {
        // regression: unquoted newlines/CRs corrupted the CSV row structure
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["multi\nline".into(), "carriage\rreturn".into()]);
        t.row(&["plain".into(), "also plain".into()]);
        let dir = std::env::temp_dir().join("gpupower_test_csv_nl");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.contains("\"multi\nline\""));
        assert!(s.contains("\"carriage\rreturn\""));
        // a CSV reader honouring quotes sees exactly 3 records: count the
        // line breaks that are outside quoted cells
        let mut in_quotes = false;
        let mut records = 0;
        for ch in s.chars() {
            match ch {
                '"' => in_quotes = !in_quotes,
                '\n' if !in_quotes => records += 1,
                _ => {}
            }
        }
        assert_eq!(records, 3, "header + 2 rows:\n{s}");
    }
}
