//! PJRT artifact runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path bridge: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`, one cached
//! executable per entry point (compilation happens once, at load).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

/// Static artifact geometry, mirrored from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub nsize: usize,
    pub block: usize,
    pub trace_len: usize,
    pub nq: usize,
    pub ngrid: usize,
    pub np: usize,
}

impl Manifest {
    /// Parse the flat integer fields out of the manifest JSON. The file is
    /// machine-generated with a fixed shape, so a targeted scan (no JSON
    /// dependency in this offline environment) is sufficient and is covered
    /// by the artifact integration tests.
    pub fn parse(text: &str) -> Result<Self> {
        let get = |key: &str| -> Result<usize> {
            let pat = format!("\"{key}\":");
            let at = text
                .find(&pat)
                .ok_or_else(|| anyhow!("manifest missing key '{key}'"))?;
            let rest = &text[at + pat.len()..];
            let digits: String =
                rest.chars().skip_while(|c| c.is_whitespace()).take_while(|c| c.is_ascii_digit()).collect();
            digits.parse::<usize>().with_context(|| format!("manifest key '{key}'"))
        };
        Ok(Manifest {
            nsize: get("nsize")?,
            block: get("block")?,
            trace_len: get("trace_len")?,
            nq: get("nq")?,
            ngrid: get("ngrid")?,
            np: get("np")?,
        })
    }
}

/// Loaded-and-compiled artifact bundle.
pub struct ArtifactRuntime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    pub dir: PathBuf,
}

impl std::fmt::Debug for ArtifactRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactRuntime")
            .field("dir", &self.dir)
            .field("entries", &self.exes.keys().collect::<Vec<_>>())
            .finish()
    }
}

/// Entry points in the artifact bundle.
pub const ENTRIES: [&str; 4] =
    ["fma_chain", "boxcar_emulate", "window_loss_grid", "energy_pipeline"];

impl ArtifactRuntime {
    /// Load every artifact from `dir` and compile it on the CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::parse(
            &std::fs::read_to_string(dir.join("manifest.json"))
                .with_context(|| format!("reading {}/manifest.json — run `make artifacts`", dir.display()))?,
        )?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut exes = HashMap::new();
        for name in ENTRIES {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            exes.insert(name.to_string(), exe);
        }
        Ok(ArtifactRuntime { client, exes, manifest, dir })
    }

    /// Load from the conventional `artifacts/` directory next to the
    /// workspace root (honours `GPUPOWER_ARTIFACTS` env override).
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("GPUPOWER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> &xla::PjRtLoadedExecutable {
        &self.exes[name]
    }

    /// Execute the FMA-chain benchmark kernel (the paper's Listing 1 load)
    /// and return (output vector, wall-clock execution time).
    ///
    /// Wall-clock is linear in `niter` (Fig. 5) — the coordinator regresses
    /// this to calibrate the square-wave high state.
    pub fn fma_chain(&self, niter: i32, x: &[f32]) -> Result<(Vec<f32>, Duration)> {
        if x.len() != self.manifest.nsize {
            return Err(anyhow!("fma_chain expects {} elements, got {}", self.manifest.nsize, x.len()));
        }
        let niter_l = xla::Literal::vec1(&[niter]);
        let x_l = xla::Literal::vec1(x);
        let start = Instant::now();
        let result = self
            .exe("fma_chain")
            .execute::<xla::Literal>(&[niter_l, x_l])
            .map_err(|e| anyhow!("fma_chain execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fma_chain readback: {e:?}"))?;
        let elapsed = start.elapsed();
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        Ok((out, elapsed))
    }

    /// Emulate nvidia-smi readings from a ground-truth trace: trailing
    /// `window` (in samples) mean at each of the `nq` sample indices.
    pub fn boxcar_emulate(&self, trace: &[f32], window: i32, sample_idx: &[i32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        if trace.len() != m.trace_len || sample_idx.len() != m.nq {
            return Err(anyhow!(
                "boxcar_emulate expects trace[{}], idx[{}]; got {}/{}",
                m.trace_len, m.nq, trace.len(), sample_idx.len()
            ));
        }
        let result = self
            .exe("boxcar_emulate")
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(trace),
                xla::Literal::vec1(&[window]),
                xla::Literal::vec1(sample_idx),
            ])
            .map_err(|e| anyhow!("boxcar_emulate execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        result
            .to_tuple1()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))
    }

    /// Evaluate the shape-normalised MSE loss for `ngrid` candidate windows
    /// in one fused XLA call (the Fig. 12 grid scan).
    pub fn window_loss_grid(
        &self,
        trace: &[f32],
        observed: &[f32],
        sample_idx: &[i32],
        windows: &[i32],
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        if trace.len() != m.trace_len
            || observed.len() != m.nq
            || sample_idx.len() != m.nq
            || windows.len() != m.ngrid
        {
            return Err(anyhow!("window_loss_grid shape mismatch"));
        }
        let result = self
            .exe("window_loss_grid")
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(trace),
                xla::Literal::vec1(observed),
                xla::Literal::vec1(sample_idx),
                xla::Literal::vec1(windows),
            ])
            .map_err(|e| anyhow!("window_loss_grid execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        result
            .to_tuple1()
            .map_err(|e| anyhow!("{e:?}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e:?}"))
    }

    /// Good-practice energy post-processing: trapezoidal integration with
    /// rise-time discard and timestamp shift. Returns (joules, seconds).
    pub fn energy_pipeline(
        &self,
        power: &[f32],
        ts: &[f32],
        valid: &[f32],
        shift_s: f32,
        discard_until_s: f32,
    ) -> Result<(f64, f64)> {
        let m = &self.manifest;
        if power.len() != m.np || ts.len() != m.np || valid.len() != m.np {
            return Err(anyhow!("energy_pipeline expects [{}] inputs", m.np));
        }
        let result = self
            .exe("energy_pipeline")
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(power),
                xla::Literal::vec1(ts),
                xla::Literal::vec1(valid),
                xla::Literal::vec1(&[shift_s]),
                xla::Literal::vec1(&[discard_until_s]),
            ])
            .map_err(|e| anyhow!("energy_pipeline execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (e, d) = result.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        let e = e.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let d = d.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((e as f64, d as f64))
    }

    /// Pack a (t, W) series into the fixed-size energy-pipeline inputs.
    pub fn pack_series(&self, series: &[(f64, f64)]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let np = self.manifest.np;
        if series.len() > np {
            return Err(anyhow!("series of {} exceeds pipeline capacity {}", series.len(), np));
        }
        let mut power = vec![0.0f32; np];
        let mut ts = vec![0.0f32; np];
        let mut valid = vec![0.0f32; np];
        for (i, &(t, w)) in series.iter().enumerate() {
            ts[i] = t as f32;
            power[i] = w as f32;
            valid[i] = 1.0;
        }
        Ok((power, ts, valid))
    }
}
