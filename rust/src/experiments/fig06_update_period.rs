//! Fig. 6: histogram of the measured power update period (V100 → 20 ms,
//! A100 → ~101 ms).

use crate::estimator::stats::{histogram, median};
use crate::report::{f, Table};
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};
use crate::smi::NvidiaSmi;

/// Result for one GPU.
#[derive(Debug, Clone)]
pub struct UpdatePeriodResult {
    pub model: &'static str,
    /// All observed update periods, seconds.
    pub periods: Vec<f64>,
    pub median_s: f64,
    /// Histogram over 0..0.2 s, 50 bins.
    pub hist: (Vec<f64>, Vec<usize>),
}

/// Measure one model's update period distribution.
pub fn run_one(model: &str, driver: DriverEpoch, field: PowerField, seed: u64) -> Option<UpdatePeriodResult> {
    let device = GpuDevice::new(find_model(model)?, 0, seed);
    let act = ActivitySignal::square_wave(0.2, 0.02, 0.5, 1.0, 280);
    let truth = device.synthesize(&act, 0.0, 6.5);
    let smi = NvidiaSmi::attach(device, driver, &truth, seed ^ 0x66);
    let log = smi.poll(field, 0.002, 0.3, 6.3);
    let periods = log.update_periods();
    if periods.len() < 5 {
        return None;
    }
    let median_s = median(&periods);
    let hist = histogram(&periods, 0.0, 0.2, 50);
    Some(UpdatePeriodResult { model: find_model(model).unwrap().name, periods, median_s, hist })
}

/// The paper's Fig. 6 pair (V100, A100) plus any extra models.
pub fn run(models: &[&str], seed: u64) -> Vec<UpdatePeriodResult> {
    models
        .iter()
        .filter_map(|m| run_one(m, DriverEpoch::Pre530, PowerField::Draw, seed))
        .collect()
}

/// Tabulate medians.
pub fn table(results: &[UpdatePeriodResult]) -> Table {
    let mut t = Table::new(
        "Fig. 6 — power update period (median of observed change intervals)",
        &["GPU", "median ms", "n samples"],
    );
    for r in results {
        t.row(&[r.model.into(), f(r.median_s * 1000.0, 1), r.periods.len().to_string()]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_and_a100_medians_match_paper() {
        let rs = run(&["V100 PCIe", "A100 PCIe-40G"], 9);
        assert_eq!(rs.len(), 2);
        assert!((rs[0].median_s - 0.020).abs() < 0.004, "V100 {}", rs[0].median_s);
        assert!((rs[1].median_s - 0.100).abs() < 0.012, "A100 {}", rs[1].median_s);
    }

    #[test]
    fn histogram_peaks_at_median() {
        let r = run_one("V100 PCIe", DriverEpoch::Pre530, PowerField::Draw, 5).unwrap();
        let (edges, counts) = &r.hist;
        let peak_bin = counts.iter().enumerate().max_by_key(|(_, c)| **c).unwrap().0;
        let peak_center = (edges[peak_bin] + edges[peak_bin + 1]) / 2.0;
        assert!((peak_center - r.median_s).abs() < 0.01);
    }
}
