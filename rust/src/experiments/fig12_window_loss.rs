//! Fig. 12: the window-estimation loss function for three representative
//! GPUs — minima at 10/20 ms (GTX 1080 Ti), 25/100 ms (A100) and
//! 100/100 ms (RTX 3090), identical whether the reference is the PMD trace
//! or the commanded square wave.
//!
//! With an [`ArtifactRuntime`] the whole grid is evaluated by the
//! `window_loss_grid` HLO artifact in one fused call.

use crate::estimator::boxcar::window_loss;
use crate::pmd::Pmd;
use crate::report::{f, Table};
use crate::runtime::ArtifactRuntime;
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, sensor_pipeline, DriverEpoch, PipelineKind, PowerField};
use crate::smi::NvidiaSmi;

/// A loss curve for one GPU.
#[derive(Debug, Clone)]
pub struct LossCurve {
    pub model: &'static str,
    /// Candidate windows, ms.
    pub windows_ms: Vec<f64>,
    /// Loss per candidate (PMD reference).
    pub loss_pmd: Vec<f64>,
    /// Loss per candidate (square-wave reference).
    pub loss_square: Vec<f64>,
    /// argmin (PMD), ms.
    pub best_pmd_ms: f64,
    /// argmin (square wave), ms.
    pub best_square_ms: f64,
    /// Ground-truth window, ms.
    pub true_window_ms: f64,
    pub used_artifact: bool,
}

/// The paper's three representative GPUs.
pub const MODELS: [&str; 3] = ["GTX 1080 Ti", "A100 PCIe-40G", "RTX 3090"];

/// Run the loss scan for one model.
pub fn run_one(model: &str, seed: u64, rt: Option<&ArtifactRuntime>) -> LossCurve {
    let m = find_model(model).unwrap();
    let device = GpuDevice::new(m, 0, seed);
    let (driver, field) = (DriverEpoch::Post530, PowerField::Instant);
    let spec = sensor_pipeline(m.generation, field, driver);
    let update_s = spec.update_ms / 1000.0;
    let true_window_ms = match spec.kind {
        PipelineKind::Boxcar { window_ms } => window_ms,
        _ => f64::NAN,
    };

    // aliasing load: period = 3/4 of update period
    let period_s = update_s * 0.75;
    let act = ActivitySignal::square_wave(0.3, period_s, 0.5, 1.0, (8.5 / period_s) as usize);
    let truth = device.synthesize(&act, 0.0, 9.0);
    let smi = NvidiaSmi::attach(device.clone(), driver, &truth, seed ^ 0x12C);
    let pmd = Pmd::new(seed).measure(&device, &truth);

    // square-wave reference (commanded levels)
    let hi = device.steady_power_w(1.0) as f32;
    let lo = device.steady_power_w(0.0) as f32;
    let square = crate::sim::trace::PowerTrace::from_samples(
        pmd.hz,
        0.0,
        (0..pmd.len())
            .map(|i| if act.util_at(i as f64 / pmd.hz) > 0.0 { hi } else { lo })
            .collect(),
    );

    let (ts, observed): (Vec<f64>, Vec<f64>) = smi
        .stream(field)
        .readings
        .iter()
        .filter(|r| r.t >= 1.0)
        .map(|r| (r.t, r.watts))
        .unzip();

    // grid: 64 candidates up to 1.5× the update period
    let grid_n = rt.map(|r| r.manifest.ngrid).unwrap_or(64);
    let windows_ms: Vec<f64> =
        (1..=grid_n).map(|i| i as f64 / grid_n as f64 * 1.5 * spec.update_ms).collect();

    let eval = |reference: &crate::sim::trace::PowerTrace| -> (Vec<f64>, bool) {
        match rt {
            Some(rt) if reference.len() == rt.manifest.trace_len && ts.len() <= rt.manifest.nq => {
                let mut idx: Vec<i32> = ts.iter().map(|&t| reference.index_of(t) as i32).collect();
                let mut obs: Vec<f32> = observed.iter().map(|&v| v as f32).collect();
                // pad by repeating the last points (keeps the shape stats stable)
                idx.resize(rt.manifest.nq, *idx.last().unwrap());
                obs.resize(rt.manifest.nq, *obs.last().unwrap());
                let wins: Vec<i32> =
                    windows_ms.iter().map(|&w| (w / 1000.0 * reference.hz).round() as i32).collect();
                let losses = rt
                    .window_loss_grid(&reference.samples, &obs, &idx, &wins)
                    .expect("window_loss_grid artifact");
                (losses.iter().map(|&l| l as f64).collect(), true)
            }
            _ => {
                let prefix = reference.prefix_sums();
                (
                    windows_ms
                        .iter()
                        .map(|&w| window_loss(reference, &prefix, &ts, &observed, w / 1000.0))
                        .collect(),
                    false,
                )
            }
        }
    };

    let (loss_pmd, used_a) = eval(&pmd);
    let (loss_square, used_b) = eval(&square);
    let argmin = |losses: &[f64]| {
        let i = losses
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        windows_ms[i]
    };
    LossCurve {
        model: m.name,
        best_pmd_ms: argmin(&loss_pmd),
        best_square_ms: argmin(&loss_square),
        windows_ms,
        loss_pmd,
        loss_square,
        true_window_ms,
        used_artifact: used_a && used_b,
    }
}

/// Run all three models.
pub fn run(seed: u64, rt: Option<&ArtifactRuntime>) -> Vec<LossCurve> {
    MODELS.iter().map(|m| run_one(m, seed, rt)).collect()
}

/// Tabulate.
pub fn table(curves: &[LossCurve]) -> Table {
    let mut t = Table::new(
        "Fig. 12 — window-estimation loss minima",
        &["GPU", "true ms", "argmin (PMD) ms", "argmin (square) ms", "artifact"],
    );
    for c in curves {
        t.row(&[
            c.model.into(),
            f(c.true_window_ms, 0),
            f(c.best_pmd_ms, 1),
            f(c.best_square_ms, 1),
            c.used_artifact.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minima_match_ground_truth_windows() {
        for c in run(80, None) {
            let tol = (c.true_window_ms * 0.35).max(6.0);
            assert!(
                (c.best_pmd_ms - c.true_window_ms).abs() < tol,
                "{}: PMD argmin {} vs true {}",
                c.model,
                c.best_pmd_ms,
                c.true_window_ms
            );
            assert!(
                (c.best_square_ms - c.true_window_ms).abs() < tol,
                "{}: square argmin {} vs true {}",
                c.model,
                c.best_square_ms,
                c.true_window_ms
            );
        }
    }

    #[test]
    fn pmd_and_square_agree() {
        for c in run(81, None) {
            let d = (c.best_pmd_ms - c.best_square_ms).abs();
            assert!(d <= (c.true_window_ms * 0.3).max(6.0), "{}: {}", c.model, d);
        }
    }
}
