//! Fig. 7: the four transient-response classes.
//!
//! Case 1 — instant actual rise, smi follows at the next update (H100
//! instant). Case 2 — actual power ramps over hundreds of ms, smi tracks
//! it (RTX 3090). Case 3 — smi lags linearly over 1 s (1 s average
//! window). Case 4 — logarithmic growth (Kepler/Maxwell RC distortion).

use super::common::{probe_transient, TransientClass, TransientResult};
use crate::report::{f, Table};
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};

/// One scenario of the figure.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub label: &'static str,
    pub model: &'static str,
    pub driver: DriverEpoch,
    pub field: PowerField,
    pub expected: TransientClass,
}

/// The paper's four panels.
pub fn scenarios() -> Vec<Scenario> {
    vec![
        Scenario {
            label: "case 1: instant rise, next-update smi",
            model: "H100",
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            expected: TransientClass::InstantActualInstantSmi,
        },
        Scenario {
            label: "case 2: slow actual rise, tracked",
            model: "RTX 3090",
            driver: DriverEpoch::V530,
            field: PowerField::Draw,
            expected: TransientClass::SlowActualTrackedSmi,
        },
        Scenario {
            label: "case 3: linear 1 s lag (average)",
            model: "RTX A6000",
            driver: DriverEpoch::Pre530,
            field: PowerField::Draw,
            expected: TransientClass::LinearLag,
        },
        Scenario {
            label: "case 4: logarithmic (RC)",
            model: "Tesla K40",
            driver: DriverEpoch::Pre530,
            field: PowerField::Draw,
            expected: TransientClass::LogarithmicLag,
        },
    ]
}

/// Run all four scenarios.
pub fn run(seed: u64) -> Vec<(Scenario, Option<TransientResult>)> {
    scenarios()
        .into_iter()
        .map(|s| {
            let device = GpuDevice::new(find_model(s.model).unwrap(), 0, seed);
            let r = probe_transient(&device, s.driver, s.field, seed ^ 0x77);
            (s, r)
        })
        .collect()
}

/// Tabulate.
pub fn table(results: &[(Scenario, Option<TransientResult>)]) -> Table {
    let mut t = Table::new(
        "Fig. 7 — transient response classes",
        &["scenario", "GPU", "actual rise ms", "smi rise ms", "class", "matches paper"],
    );
    for (s, r) in results {
        match r {
            Some(r) => t.row(&[
                s.label.into(),
                s.model.into(),
                f(r.actual_rise_s * 1000.0, 0),
                f(r.smi_rise_s * 1000.0, 0),
                format!("{:?}", r.class),
                (r.class == s.expected).to_string(),
            ]),
            None => t.row(&[
                s.label.into(),
                s.model.into(),
                "-".into(),
                "-".into(),
                "no data".into(),
                "false".into(),
            ]),
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_four_classes_recovered() {
        let results = run(13);
        for (s, r) in &results {
            let r = r.expect(s.label);
            assert_eq!(r.class, s.expected, "{}: {:?}", s.label, r);
        }
    }
}
