//! Fig. 8: steady-state nvidia-smi vs PMD power, 7 load levels × 8 reps,
//! near-perfect linear relationship (R² = 0.9999) whose gradient ≠ 1.

use crate::estimator::linreg::{fit, LinearFit};
use crate::measure::MeasurementRig;
use crate::report::{f, Table};
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};

/// The paper's 7 load levels: idle, then SM fractions.
pub const LEVELS: [f64; 7] = [0.0, 0.01, 0.2, 0.4, 0.6, 0.8, 1.0];

/// Result of one steady-state sweep.
#[derive(Debug, Clone)]
pub struct SteadyStateResult {
    pub model: &'static str,
    /// (PMD W, smi W) pairs — 7 levels × reps.
    pub points: Vec<(f64, f64)>,
    pub fit: LinearFit,
}

/// Run the sweep on one device (default: the paper's RTX 3090).
pub fn run_device(device: GpuDevice, driver: DriverEpoch, field: PowerField, reps: usize, seed: u64) -> SteadyStateResult {
    let rig = MeasurementRig::new(device, driver, field, seed);
    let mut points = Vec::new();
    for (li, &level) in LEVELS.iter().enumerate() {
        for rep in 0..reps {
            let boot = seed ^ ((li * 100 + rep) as u64).wrapping_mul(0x9E37_79B9);
            let act = if level == 0.0 {
                ActivitySignal::idle()
            } else {
                ActivitySignal::burst(0.5, 3.0, level)
            };
            let cap = rig.capture(&act, 0.0, 4.0, boot);
            // measure once fully settled (2.5 s after the step)
            let p_pmd = cap.pmd_trace.window_mean(3.4, 0.8);
            let p_smi = match cap.smi.query(field, 3.4) {
                Some(w) => w,
                None => continue,
            };
            points.push((p_pmd, p_smi));
        }
    }
    let (xs, ys): (Vec<f64>, Vec<f64>) = points.iter().copied().unzip();
    let model = rig.device.model.name;
    SteadyStateResult { model, points, fit: fit(&xs, &ys) }
}

/// Default run: RTX 3090, instant field, 8 reps (paper setup).
pub fn run(seed: u64) -> SteadyStateResult {
    let device = GpuDevice::new(find_model("RTX 3090").unwrap(), 0, seed);
    run_device(device, DriverEpoch::Post530, PowerField::Instant, 8, seed)
}

/// Tabulate.
pub fn table(r: &SteadyStateResult) -> Table {
    let mut t = Table::new(
        format!("Fig. 8 — steady-state smi vs PMD ({})", r.model),
        &["metric", "value"],
    );
    t.row(&["points".into(), r.points.len().to_string()]);
    t.row(&["gradient".into(), f(r.fit.slope, 4)]);
    t.row(&["offset W".into(), f(r.fit.intercept, 2)]);
    t.row(&["R²".into(), f(r.fit.r2, 5)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relationship_is_linear_with_nonunit_gradient() {
        let r = run(41);
        assert!(r.fit.r2 > 0.998, "R²={}", r.fit.r2);
        // the gradient embeds the card tolerance and the PMD rail gap;
        // it must differ from exactly 1 but stay within a ±8% band
        assert!((r.fit.slope - 1.0).abs() > 0.002, "gradient exactly 1 is wrong");
        assert!((r.fit.slope - 1.0).abs() < 0.09, "gradient={}", r.fit.slope);
    }

    #[test]
    fn seven_clusters_present() {
        let r = run(42);
        assert_eq!(r.points.len(), 7 * 8);
        // clusters: idle is far from the active levels
        let mut pmds: Vec<f64> = r.points.iter().map(|p| p.0).collect();
        pmds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(pmds[8] - pmds[7] > 20.0, "idle cluster separated (pstate gap)");
    }

    #[test]
    fn power_limit_compresses_top_cluster() {
        // spacing between the 80% and 100% clusters is smaller than between
        // 60% and 80% (Fig. 8's "less further apart due to the power limit")
        let r = run(43);
        let cluster_mean = |lvl_idx: usize| {
            let chunk: Vec<f64> =
                r.points[lvl_idx * 8..(lvl_idx + 1) * 8].iter().map(|p| p.0).collect();
            crate::estimator::stats::mean(&chunk)
        };
        let d_60_80 = cluster_mean(5) - cluster_mean(4);
        let d_80_100 = cluster_mean(6) - cluster_mean(5);
        assert!(d_80_100 < d_60_80, "{d_80_100} !< {d_60_80}");
    }
}
