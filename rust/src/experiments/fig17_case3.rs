//! Fig. 17 — Case 3: averaging window (25 ms) *shorter* than the update
//! period (100 ms) — A100/H100: 75% of activity invisible. Without phase
//! shifts the error std reaches ~30%; with 4 or 8 controlled 25 ms delays
//! it collapses below ~5%.

use super::energy_cases::{run_case, CaseConfig, RepsPoint};
use crate::measure::SensorCharacterization;
use crate::report::Table;
use crate::sim::profile::{DriverEpoch, PowerField};

/// Sensor knowledge: A100 instant (25 ms / 100 ms), 100 ms rise.
pub fn sensor() -> SensorCharacterization {
    SensorCharacterization { update_s: 0.1, window_s: 0.025, rise_s: 0.1 }
}

/// Load periods: 25 ms (aligned with the window), 100 ms, 800 ms.
pub const PERIODS_S: [f64; 3] = [0.025, 0.1, 0.8];

/// Shift variants tested (consecutive, 4 shifts, 8 shifts).
pub const SHIFT_VARIANTS: [usize; 3] = [0, 4, 8];

/// Run one (period, shifts) cell.
pub fn run_cell(period_s: f64, shifts: usize, trials: usize, seed: u64) -> Vec<RepsPoint> {
    run_case(&CaseConfig {
        model: "A100 PCIe-40G",
        driver: DriverEpoch::Post530,
        field: PowerField::Instant,
        sensor: sensor(),
        period_s,
        reps_list: vec![16, 32, 64],
        trials,
        shifts,
        seed,
    })
}

/// Run the full grid.
pub fn run(trials: usize, seed: u64) -> Vec<(f64, usize, Vec<RepsPoint>)> {
    let mut out = Vec::new();
    for &p in &PERIODS_S {
        for &s in &SHIFT_VARIANTS {
            out.push((p, s, run_cell(p, s, trials, seed)));
        }
    }
    out
}

/// Tabulate.
pub fn tables(results: &[(f64, usize, Vec<RepsPoint>)]) -> Vec<Table> {
    results
        .iter()
        .map(|(p, s, pts)| {
            super::energy_cases::table(
                &format!(
                    "Fig. 17 — Case 3 (25/100 ms), load period {:.0} ms, {} shifts",
                    p * 1000.0,
                    s
                ),
                pts,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shifts_reduce_error_spread_on_100ms_load() {
        // the paper's central Case-3 result: at the aliased 100 ms period,
        // 0 shifts -> huge std; 8 shifts -> small std
        let no_shift = run_cell(0.1, 0, 8, 170);
        let with_shift = run_cell(0.1, 8, 8, 170);
        let s0 = no_shift.last().unwrap().corrected_std_pct;
        let s8 = with_shift.last().unwrap().corrected_std_pct;
        assert!(s0 > 6.0, "unshifted std should be large, got {s0}");
        assert!(s8 < s0 * 0.7, "8 shifts must cut the std: {s0} -> {s8}");
    }

    #[test]
    fn aligned_25ms_load_behaves_like_case1() {
        // when the activity period matches the window, everything is seen
        let pts = run_cell(0.025, 0, 6, 171);
        let last = pts.last().unwrap();
        assert!(last.corrected_std_pct < 6.0, "std={}", last.corrected_std_pct);
        assert!(last.corrected_mean_pct.abs() < 10.0, "mean={}", last.corrected_mean_pct);
    }
}
