//! Fig. 19 / §6: the Grace Hopper GH200 evaluation — separate then
//! simultaneous CPU and GPU loads, captured by nvidia-smi (Average /
//! Instant), the CPU-domain sensor, and the ACPI 50 ms sensor.

use crate::estimator::stats::median;
use crate::report::{f, Table};
use crate::sim::activity::ActivitySignal;
use crate::sim::superchip::{Superchip, SuperchipCapture};

/// Scalar findings extracted from the capture.
#[derive(Debug)]
pub struct Fig19Result {
    pub capture: SuperchipCapture,
    /// Instant − Average at idle, watts (paper: consistently positive).
    pub idle_gap_w: f64,
    /// Instant rise during the CPU-only phase, watts.
    pub instant_cpu_response_w: f64,
    /// Average rise during the CPU-only phase, watts (should be ~0).
    pub average_cpu_response_w: f64,
    /// GPU-domain coverage (window/update): 20/100 = 0.2.
    pub gpu_coverage: f64,
    /// CPU-domain coverage: 10/100 = 0.1.
    pub cpu_coverage: f64,
    /// Largest ACPI deviation from its median, watts (paper: >100 W).
    pub acpi_max_noise_w: f64,
}

/// Run the §6 protocol: CPU burst at 1–3 s, GPU burst at 4–6 s, both at
/// 7–9 s.
pub fn run(seed: u64) -> Fig19Result {
    let chip = Superchip::new(seed);
    let cpu = {
        let mut a = ActivitySignal::burst(1.0, 2.0, 1.0);
        a.push(7.0, 2.0, 1.0);
        a
    };
    let gpu = {
        let mut a = ActivitySignal::burst(4.0, 2.0, 1.0);
        a.push(7.0, 2.0, 1.0);
        a
    };
    let capture = chip.capture(&gpu, &cpu, 0.0, 10.0);

    let v = |s: &crate::sim::sensor::SensorStream, t: f64| s.value_at(t).unwrap_or(f64::NAN);
    let idle_gap_w = v(&capture.smi_instant, 0.9) - v(&capture.smi_average, 0.9);
    let instant_cpu_response_w = v(&capture.smi_instant, 2.6) - v(&capture.smi_instant, 0.9);
    let average_cpu_response_w = v(&capture.smi_average, 2.9) - v(&capture.smi_average, 0.9);
    let acpi_vals: Vec<f64> = capture.acpi.iter().map(|p| p.1).collect();
    let acpi_med = median(&acpi_vals);
    let acpi_max_noise_w = acpi_vals.iter().map(|x| (x - acpi_med).abs()).fold(0.0, f64::max);

    Fig19Result {
        capture,
        idle_gap_w,
        instant_cpu_response_w,
        average_cpu_response_w,
        gpu_coverage: 0.020 / 0.100,
        cpu_coverage: 0.010 / 0.100,
        acpi_max_noise_w,
    }
}

/// Tabulate.
pub fn table(r: &Fig19Result) -> Table {
    let mut t = Table::new("Fig. 19 — GH200 Grace Hopper evaluation", &["finding", "value"]);
    t.row(&["Instant − Average at idle (W)".into(), f(r.idle_gap_w, 1)]);
    t.row(&["Instant response to CPU-only load (W)".into(), f(r.instant_cpu_response_w, 1)]);
    t.row(&["Average response to CPU-only load (W)".into(), f(r.average_cpu_response_w, 1)]);
    t.row(&["GPU activity measured (window/update)".into(), format!("{:.0}%", r.gpu_coverage * 100.0)]);
    t.row(&["CPU activity measured (window/update)".into(), format!("{:.0}%", r.cpu_coverage * 100.0)]);
    t.row(&["max ACPI noise excursion (W)".into(), f(r.acpi_max_noise_w, 0)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instant_is_module_level() {
        let r = run(190);
        assert!(r.idle_gap_w > 50.0, "Instant > Average at idle: {}", r.idle_gap_w);
        assert!(r.instant_cpu_response_w > 150.0, "Instant reacts to CPU: {}", r.instant_cpu_response_w);
        assert!(r.average_cpu_response_w.abs() < 40.0, "Average ignores CPU: {}", r.average_cpu_response_w);
    }

    #[test]
    fn coverage_is_worse_than_a100() {
        let r = run(191);
        assert!(r.gpu_coverage < 0.25, "GPU 20% < A100's 25%");
        assert!(r.cpu_coverage < r.gpu_coverage, "CPU 10% is the worst");
    }

    #[test]
    fn acpi_noise_exceeds_100w() {
        let r = run(192);
        assert!(r.acpi_max_noise_w > 100.0, "{}", r.acpi_max_noise_w);
    }
}
