//! Fig. 11: reconstruct the nvidia-smi series from (a) the PMD trace and
//! (b) the commanded square wave, with the boxcar emulation model — both
//! must match the original, which is what lets the window experiment run
//! on GPUs without a PMD attached.
//!
//! When an [`ArtifactRuntime`] is supplied, the emulation runs through the
//! `boxcar_emulate` HLO artifact (the L2/L1 path); otherwise pure Rust.

use crate::estimator::boxcar::{emulate_smi, normalise};
use crate::pmd::Pmd;
use crate::report::{f, Table};
use crate::runtime::ArtifactRuntime;
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};
use crate::sim::trace::PowerTrace;
use crate::smi::NvidiaSmi;

/// Result: original + two reconstructions (normalised shape vectors).
#[derive(Debug, Clone)]
pub struct Fig11Result {
    pub timestamps: Vec<f64>,
    pub original: Vec<f64>,
    pub from_pmd: Vec<f64>,
    pub from_square: Vec<f64>,
    /// Shape-space MSE of each reconstruction against the original.
    pub mse_pmd: f64,
    pub mse_square: f64,
    /// True if the HLO artifact path was used.
    pub used_artifact: bool,
}

fn shape_mse(a: &[f64], b: &[f64]) -> f64 {
    let mut x = a.to_vec();
    let mut y = b.to_vec();
    if !normalise(&mut x) || !normalise(&mut y) {
        return f64::INFINITY;
    }
    x.iter().zip(&y).map(|(p, q)| (p - q) * (p - q)).sum::<f64>() / x.len() as f64
}

/// The ideal square-wave power trace (commanded levels, no dynamics).
fn square_trace(device: &GpuDevice, act: &ActivitySignal, t0: f64, t1: f64, hz: f64) -> PowerTrace {
    let n = ((t1 - t0) * hz) as usize;
    let hi = device.steady_power_w(1.0) as f32;
    let lo = device.steady_power_w(0.0) as f32;
    let samples = (0..n)
        .map(|i| if act.util_at(t0 + i as f64 / hz) > 0.0 { hi } else { lo })
        .collect();
    PowerTrace::from_samples(hz, t0, samples)
}

/// Run on the A100 with the paper's 154 ms load.
pub fn run(seed: u64, rt: Option<&ArtifactRuntime>) -> Fig11Result {
    let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, seed);
    let act = ActivitySignal::square_wave(0.3, 0.154, 0.5, 1.0, 56);
    let truth = device.synthesize(&act, 0.0, 9.0);
    let smi = NvidiaSmi::attach(device.clone(), DriverEpoch::Post530, &truth, seed ^ 0xF11);
    let pmd = Pmd::new(seed).measure(&device, &truth);
    let square = square_trace(&device, &act, 0.0, 9.0, pmd.hz);

    // discard the first second (paper step 4)
    let readings: Vec<(f64, f64)> = smi
        .stream(PowerField::Instant)
        .readings
        .iter()
        .filter(|r| r.t >= 1.0)
        .map(|r| (r.t, r.watts))
        .collect();
    let (ts, original): (Vec<f64>, Vec<f64>) = readings.iter().copied().unzip();
    let window_s = 0.025;

    let (from_pmd, from_square, used_artifact) = match rt {
        Some(rt) if pmd.len() == rt.manifest.trace_len => {
            let idx: Vec<i32> = {
                let mut v: Vec<i32> = ts.iter().map(|&t| pmd.index_of(t) as i32).collect();
                v.resize(rt.manifest.nq, *v.last().unwrap_or(&0));
                v
            };
            let w = (window_s * pmd.hz).round() as i32;
            let ep = rt.boxcar_emulate(&pmd.samples, w, &idx).expect("artifact emulate");
            let es = rt.boxcar_emulate(&square.samples, w, &idx).expect("artifact emulate");
            (
                ep[..ts.len()].iter().map(|&x| x as f64).collect(),
                es[..ts.len()].iter().map(|&x| x as f64).collect(),
                true,
            )
        }
        _ => {
            let pp = pmd.prefix_sums();
            let sp = square.prefix_sums();
            (
                emulate_smi(&pmd, &pp, &ts, window_s),
                emulate_smi(&square, &sp, &ts, window_s),
                false,
            )
        }
    };

    let mse_pmd = shape_mse(&original, &from_pmd);
    let mse_square = shape_mse(&original, &from_square);
    Fig11Result { timestamps: ts, original, from_pmd, from_square, mse_pmd, mse_square, used_artifact }
}

/// Tabulate.
pub fn table(r: &Fig11Result) -> Table {
    let mut t = Table::new(
        "Fig. 11 — smi reconstruction from PMD and from the square wave (A100, 154 ms)",
        &["reconstruction", "shape MSE vs original"],
    );
    t.row(&["from PMD".into(), f(r.mse_pmd, 4)]);
    t.row(&["from square wave".into(), f(r.mse_square, 4)]);
    t.row(&["via HLO artifact".into(), r.used_artifact.to_string()]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reconstructions_match_original_shape() {
        let r = run(70, None);
        assert!(r.mse_pmd < 0.12, "PMD reconstruction MSE={}", r.mse_pmd);
        assert!(r.mse_square < 0.25, "square-wave reconstruction MSE={}", r.mse_square);
        assert!(r.original.len() > 60);
    }

    #[test]
    fn wrong_window_reconstructs_worse() {
        // sanity: emulating with the *wrong* window must fit worse than 25 ms
        let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, 71);
        let act = ActivitySignal::square_wave(0.3, 0.154, 0.5, 1.0, 56);
        let truth = device.synthesize(&act, 0.0, 9.0);
        let smi = NvidiaSmi::attach(device.clone(), DriverEpoch::Post530, &truth, 72);
        let (ts, orig): (Vec<f64>, Vec<f64>) = smi
            .stream(PowerField::Instant)
            .readings
            .iter()
            .filter(|r| r.t >= 1.0)
            .map(|r| (r.t, r.watts))
            .unzip();
        let prefix = truth.prefix_sums();
        let good = shape_mse(&orig, &emulate_smi(&truth, &prefix, &ts, 0.025));
        let bad = shape_mse(&orig, &emulate_smi(&truth, &prefix, &ts, 0.100));
        assert!(good < bad, "good={good} bad={bad}");
    }
}
