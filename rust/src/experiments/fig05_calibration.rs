//! Fig. 5: chain-length → kernel-time linearity (R² = 1.000).
//!
//! Unlike the other experiments this one exercises the *real* compute
//! artifact: the Pallas FMA-chain kernel, AOT-lowered to HLO and executed
//! on the PJRT CPU client. The wall-clock scaling replaces the paper's
//! CUDA timing; the linear fit is the same.

use anyhow::Result;

use crate::bench::calibrate::{calibrate_sweep, CalibrationSweep};
use crate::report::{f, Table};
use crate::runtime::ArtifactRuntime;

/// Result: the measured sweep and fit.
#[derive(Debug, Clone)]
pub struct Fig05Result {
    pub sweep: CalibrationSweep,
}

/// Run the calibration sweep on the loaded artifact runtime.
pub fn run(rt: &ArtifactRuntime) -> Result<Fig05Result> {
    let niters: Vec<i32> = (1..=8).map(|k| k * 1000).collect();
    let sweep = calibrate_sweep(rt, &niters, 5)?;
    Ok(Fig05Result { sweep })
}

/// Tabulate.
pub fn table(r: &Fig05Result) -> Table {
    let mut t = Table::new(
        "Fig. 5 — FMA-chain iterations vs execution time (PJRT, Pallas kernel)",
        &["niter", "measured ms", "fit ms"],
    );
    for (n, ms) in r.sweep.niters.iter().zip(&r.sweep.measured_ms) {
        t.row(&[n.to_string(), f(*ms, 3), f(r.sweep.fit.predict(*n as f64), 3)]);
    }
    t.row(&[
        "R²".into(),
        f(r.sweep.fit.r2, 4),
        format!("slope {:.3} µs/iter", r.sweep.fit.slope * 1000.0),
    ]);
    t
}
