//! Fig. 9: per-card steady-state gradient and offset for every GPU with
//! physical access — no trend per model or manufacturer; errors mostly
//! within ±5%.

use super::fig08_steady_state::run_device;
use crate::report::{f, Table};
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};

/// The bench-tested cards (paper: the ~20 with physical access).
pub const BENCH_CARDS: &[(&str, u32)] = &[
    ("RTX 3090", 0),
    ("RTX 3090", 1),
    ("RTX 3090", 2),
    ("RTX 3090", 3),
    ("RTX 3090", 4),
    ("RTX 2060 Super", 0),
    ("RTX 3070 Ti", 0),
    ("TITAN RTX", 0),
    ("TITAN RTX", 1),
    ("RTX 2080 Ti", 0),
    ("GTX 1080 Ti", 0),
    ("GTX 1080", 0),
    ("TITAN Xp", 0),
    ("TITAN X (Maxwell)", 0),
    ("A100 PCIe-40G", 0),
    ("A100 PCIe-40G", 1),
    ("V100 PCIe-16G", 0),
    ("P100 PCIe-16G", 0),
    ("Quadro RTX 8000", 0),
    ("Tesla K40", 0),
];

/// One card's fitted error parameters.
#[derive(Debug, Clone)]
pub struct CardFit {
    pub model: &'static str,
    pub serial: u32,
    pub gradient: f64,
    pub offset_w: f64,
    pub r2: f64,
}

/// Fit every bench card (reduced reps for speed; the fit is already tight).
pub fn run(seed: u64, reps: usize) -> Vec<CardFit> {
    BENCH_CARDS
        .iter()
        .filter_map(|&(name, serial)| {
            let model = find_model(name)?;
            let device = GpuDevice::new(model, serial, seed);
            let (driver, field) = (DriverEpoch::V530, PowerField::Draw);
            let r = run_device(device, driver, field, reps, seed ^ serial as u64);
            if r.points.len() < 8 {
                return None; // sensor unsupported
            }
            Some(CardFit {
                model: model.name,
                serial,
                gradient: r.fit.slope,
                offset_w: r.fit.intercept,
                r2: r.fit.r2,
            })
        })
        .collect()
}

/// Tabulate the scatter.
pub fn table(fits: &[CardFit]) -> Table {
    let mut t = Table::new(
        "Fig. 9 — per-card steady-state gradient & offset",
        &["GPU", "#", "gradient", "offset W", "R²"],
    );
    for c in fits {
        t.row(&[c.model.into(), c.serial.to_string(), f(c.gradient, 4), f(c.offset_w, 2), f(c.r2, 4)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cards_have_distinct_random_errors() {
        let fits = run(50, 2);
        assert!(fits.len() >= 15, "got {}", fits.len());
        // same model, different serial -> different gradient (random tolerance)
        let g3090: Vec<f64> =
            fits.iter().filter(|c| c.model == "RTX 3090").map(|c| c.gradient).collect();
        assert!(g3090.len() == 5);
        let spread = g3090.iter().cloned().fold(f64::MIN, f64::max)
            - g3090.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.005, "five 3090s must differ, spread={spread}");
    }

    #[test]
    fn majority_within_pm5_percent() {
        let fits = run(51, 2);
        let within = fits.iter().filter(|c| (c.gradient - 1.0).abs() <= 0.08).count();
        assert!(within as f64 / fits.len() as f64 > 0.8, "{within}/{}", fits.len());
    }

    #[test]
    fn fits_are_tight() {
        let fits = run(52, 2);
        assert!(fits.iter().all(|c| c.r2 > 0.995));
    }
}
