//! Fig. 10: a 100 ms square-wave load on the RTX 3090 vs A100 — on the
//! 3090 (window = update period) the smi readings sit flat at the midpoint;
//! on the A100 (window = ¼ period) they swing high/low with aliasing.

use crate::estimator::stats::std_dev;
use crate::report::{f, Table};
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};
use crate::smi::NvidiaSmi;

/// One GPU's aliasing behaviour under the 100 ms square wave.
#[derive(Debug, Clone)]
pub struct AliasResult {
    pub model: &'static str,
    /// smi readings in the steady region.
    pub smi_w: Vec<f64>,
    /// PMD high/low plateau means.
    pub truth_hi_w: f64,
    pub truth_lo_w: f64,
    /// Swing of the smi readings relative to the true swing, 0..1.
    pub relative_swing: f64,
    pub std_w: f64,
}

/// Run one model.
pub fn run_one(model: &str, seed: u64) -> AliasResult {
    let m = find_model(model).unwrap();
    let device = GpuDevice::new(m, 0, seed);
    // square wave: 100 ms period (slightly detuned, as the paper found its
    // generator was, which produces the aliasing sweep), 50% duty
    let act = ActivitySignal::square_wave(0.5, 0.1004, 0.5, 1.0, 75);
    let truth = device.synthesize(&act, 0.0, 8.6);
    let smi = NvidiaSmi::attach(device.clone(), DriverEpoch::Post530, &truth, seed ^ 0xA11A5);
    let readings: Vec<f64> = smi
        .stream(PowerField::Instant)
        .readings
        .iter()
        .filter(|r| r.t > 2.0 && r.t < 8.0)
        .map(|r| r.watts)
        .collect();
    // true plateau levels from windows wholly inside high/low half-cycles
    let prefix = truth.prefix_sums();
    let mut hi = Vec::new();
    let mut lo = Vec::new();
    for k in 20..70 {
        let t_hi = 0.5 + k as f64 * 0.1004 + 0.045;
        let t_lo = 0.5 + k as f64 * 0.1004 + 0.095;
        hi.push(truth.window_mean_with(&prefix, t_hi, 0.01));
        lo.push(truth.window_mean_with(&prefix, t_lo, 0.01));
    }
    let truth_hi_w = crate::estimator::stats::mean(&hi);
    let truth_lo_w = crate::estimator::stats::mean(&lo);
    let smi_max = readings.iter().cloned().fold(f64::MIN, f64::max);
    let smi_min = readings.iter().cloned().fold(f64::MAX, f64::min);
    let relative_swing = (smi_max - smi_min) / (truth_hi_w - truth_lo_w).max(1.0);
    AliasResult {
        model: m.name,
        std_w: std_dev(&readings),
        smi_w: readings,
        truth_hi_w,
        truth_lo_w,
        relative_swing,
    }
}

/// The paper's pair.
pub fn run(seed: u64) -> (AliasResult, AliasResult) {
    (run_one("RTX 3090", seed), run_one("A100 PCIe-40G", seed))
}

/// Tabulate.
pub fn table(r3090: &AliasResult, ra100: &AliasResult) -> Table {
    let mut t = Table::new(
        "Fig. 10 — 100 ms square wave: full-window flattening vs part-time swing",
        &["GPU", "true hi W", "true lo W", "smi std W", "relative swing"],
    );
    for r in [r3090, ra100] {
        t.row(&[
            r.model.into(),
            f(r.truth_hi_w, 0),
            f(r.truth_lo_w, 0),
            f(r.std_w, 1),
            f(r.relative_swing, 2),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtx3090_flat_a100_swings() {
        let (r3090, ra100) = run(60);
        assert!(
            r3090.relative_swing < 0.45,
            "3090 should flatten, swing={}",
            r3090.relative_swing
        );
        assert!(ra100.relative_swing > 0.6, "A100 should swing, swing={}", ra100.relative_swing);
        assert!(ra100.std_w > 3.0 * r3090.std_w, "{} vs {}", ra100.std_w, r3090.std_w);
    }

    #[test]
    fn flat_value_is_midpoint() {
        let (r3090, _) = run(61);
        let mid = (r3090.truth_hi_w + r3090.truth_lo_w) / 2.0;
        let mean_smi = crate::estimator::stats::mean(&r3090.smi_w);
        // the card tolerance scales the reading; allow that margin
        assert!((mean_smi - mid).abs() / mid < 0.12, "mean={mean_smi} mid={mid}");
    }
}
