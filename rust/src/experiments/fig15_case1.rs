//! Fig. 15 — Case 1: averaging window == power update period (RTX 3090,
//! instant option, 100 ms / 100 ms). Error vs repetition count for short /
//! medium / long loads; corrections (discard rise reps, shift 100 ms)
//! reach the steady-state margin with fewer repetitions.

use super::energy_cases::{default_reps, run_case, CaseConfig, RepsPoint};
use crate::measure::SensorCharacterization;
use crate::report::Table;
use crate::sim::profile::{DriverEpoch, PowerField};

/// Sensor knowledge for this case (from the micro-benchmarks).
pub fn sensor() -> SensorCharacterization {
    SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 }
}

/// The three load periods: 25%, 100%, 800% of the update period.
pub const PERIODS_S: [f64; 3] = [0.025, 0.1, 0.8];

/// Run one load period.
pub fn run_period(period_s: f64, trials: usize, seed: u64) -> Vec<RepsPoint> {
    run_case(&CaseConfig {
        model: "RTX 3090",
        driver: DriverEpoch::Post530,
        field: PowerField::Instant,
        sensor: sensor(),
        period_s,
        reps_list: default_reps(),
        trials,
        shifts: 0,
        seed,
    })
}

/// Run all three periods.
pub fn run(trials: usize, seed: u64) -> Vec<(f64, Vec<RepsPoint>)> {
    PERIODS_S.iter().map(|&p| (p, run_period(p, trials, seed))).collect()
}

/// Tabulate.
pub fn tables(results: &[(f64, Vec<RepsPoint>)]) -> Vec<Table> {
    results
        .iter()
        .map(|(p, pts)| {
            super::energy_cases::table(
                &format!("Fig. 15 — Case 1 (100/100 ms), load period {:.0} ms", p * 1000.0),
                pts,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_converges_with_repetitions() {
        let pts = run_period(0.1, 6, 150);
        let first = &pts[0];
        let last = pts.last().unwrap();
        // more reps -> smaller spread
        assert!(
            last.naive_std_pct < first.naive_std_pct,
            "std must shrink: {} -> {}",
            first.naive_std_pct,
            last.naive_std_pct
        );
        // converged error should approximate the steady-state margin (< ~10%)
        assert!(last.naive_mean_pct.abs() < 10.0, "mean={}", last.naive_mean_pct);
    }

    #[test]
    fn few_repetitions_underestimate() {
        // the rise time means early reps read low -> negative error at reps=1
        let pts = run_period(0.1, 8, 151);
        assert!(pts[0].naive_mean_pct < -4.0, "reps=1 error {}", pts[0].naive_mean_pct);
    }

    #[test]
    fn correction_accelerates_convergence() {
        let pts = run_period(0.1, 6, 152);
        // at a mid repetition count, corrected |error - converged| is smaller
        let converged = pts.last().unwrap().corrected_mean_pct;
        let mid = &pts[3]; // 8 reps
        assert!(
            (mid.corrected_mean_pct - converged).abs()
                <= (mid.naive_mean_pct - converged).abs() + 0.5,
            "corrected {} vs naive {} (converged {})",
            mid.corrected_mean_pct,
            mid.naive_mean_pct,
            converged
        );
    }
}
