//! Fig. 1: the motivating observation — nvidia-smi can report drastically
//! different power (80–200 W) for the *same* CUDA kernel on an A100,
//! because only 25 ms of every 100 ms is measured.

use crate::report::{f, Table};
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};
use crate::smi::NvidiaSmi;

/// Result: the smi readings observed while one 325 ms program (kernel run
/// 4 times) executes.
#[derive(Debug, Clone)]
pub struct Fig01Result {
    /// (time, reported W) during the program.
    pub readings: Vec<(f64, f64)>,
    pub min_w: f64,
    pub max_w: f64,
    /// Kernel-iteration start times (the green dotted lines).
    pub iteration_starts: Vec<f64>,
}

/// Run the Fig. 1 scenario with a given boot seed (phase).
pub fn run(seed: u64) -> Fig01Result {
    let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, seed);
    // a 325 ms program: the kernel executed 4 times (~45 ms each with
    // ~36 ms gaps, as in the figure)
    let t0 = 1.0;
    let mut act = ActivitySignal::idle();
    let mut starts = Vec::new();
    for k in 0..4 {
        let t = t0 + k as f64 * 0.0813;
        starts.push(t);
        act.push(t, 0.045, 1.0);
    }
    let truth = device.synthesize(&act, 0.0, 2.5);
    let smi = NvidiaSmi::attach(device, DriverEpoch::Post530, &truth, seed ^ 0xF1);
    let readings: Vec<(f64, f64)> = smi
        .stream(PowerField::Instant)
        .readings
        .iter()
        .filter(|r| r.t >= t0 - 0.05 && r.t <= t0 + 0.375)
        .map(|r| (r.t, r.watts))
        .collect();
    let min_w = readings.iter().map(|r| r.1).fold(f64::MAX, f64::min);
    let max_w = readings.iter().map(|r| r.1).fold(f64::MIN, f64::max);
    Fig01Result { readings, min_w, max_w, iteration_starts: starts }
}

/// Run across several boot phases and tabulate the spread.
pub fn table(seeds: &[u64]) -> Table {
    let mut t = Table::new(
        "Fig. 1 — same kernel, drastically different reported power (A100)",
        &["boot phase #", "min W", "max W", "spread W"],
    );
    for (i, &s) in seeds.iter().enumerate() {
        let r = run(s);
        t.row(&[format!("{i}"), f(r.min_w, 1), f(r.max_w, 1), f(r.max_w - r.min_w, 1)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readings_span_a_wide_range_across_phases() {
        // across boot phases the same program must show a large spread
        let mut global_min = f64::MAX;
        let mut global_max = f64::MIN;
        for s in 0..8 {
            let r = run(s);
            global_min = global_min.min(r.min_w);
            global_max = global_max.max(r.max_w);
        }
        assert!(global_max - global_min > 80.0, "spread {global_min}..{global_max}");
    }

    #[test]
    fn four_iterations_marked() {
        let r = run(1);
        assert_eq!(r.iteration_starts.len(), 4);
        assert!(!r.readings.is_empty());
    }
}
