//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **A1 — shift count**: how many controlled delays does Case 3 actually
//!   need? (the paper picks 8; sweep 0/2/4/8/16)
//! * **A2 — estimator grid size**: the Fig. 12 grid scan seeds Nelder-Mead;
//!   how coarse can it be before the estimate degrades?
//! * **A3 — polling period**: how fast must the logger poll to resolve the
//!   update period?
//! * **A4 — energy counter design**: continuous vs windowed integration
//!   (the future-work extension; smi::energy_counter).
//! * **A5 — fault robustness**: good-practice error under sample dropout.

use crate::estimator::boxcar::{estimate_window, EstimatorConfig};
use crate::estimator::stats::{mean, median, std_dev};
use crate::measure::energy::{mean_power, shift_earlier};
use crate::measure::{MeasurementRig, RepeatableLoad, SensorCharacterization};
use crate::report::{f, Table};
use crate::sim::faults::drop_samples;
use crate::sim::profile::{find_model, DriverEpoch, PipelineSpec, PowerField};
use crate::sim::sensor::run_pipeline;
use crate::sim::{ActivitySignal, GpuDevice};
use crate::smi::energy_counter::{run_counter, CounterDesign};
use crate::smi::NvidiaSmi;

/// A1: Case-3 error std vs number of controlled shifts.
pub fn shift_count_ablation(trials: usize, seed: u64) -> Table {
    let mut t = Table::new(
        "A1 — Case 3 (A100, 100 ms load): error std vs controlled shifts",
        &["shifts", "corrected mean %", "corrected std %"],
    );
    for shifts in [0usize, 2, 4, 8, 16] {
        let pts = super::fig17_case3::run_cell(0.1, shifts, trials, seed);
        let last = pts.last().unwrap();
        t.row(&[
            shifts.to_string(),
            f(last.corrected_mean_pct, 2),
            f(last.corrected_std_pct, 2),
        ]);
    }
    t
}

/// A2: window-estimate error vs grid size (A100, 25/100).
pub fn grid_size_ablation(runs: usize, seed: u64) -> Table {
    let mut t = Table::new(
        "A2 — window estimator: |error| vs coarse-grid size (A100 25/100 ms)",
        &["grid points", "median |err| ms", "mean evals"],
    );
    for grid in [0usize, 4, 8, 16, 32, 64] {
        let mut errs = Vec::new();
        let mut evals = Vec::new();
        for run in 0..runs {
            let s = seed ^ ((grid * 100 + run) as u64).wrapping_mul(0x9E37_79B9);
            let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, s);
            let act = ActivitySignal::square_wave(0.3, 0.075, 0.5, 1.0, 110);
            let truth = device.synthesize(&act, 0.0, 9.0);
            let stream = run_pipeline(&device, PipelineSpec::boxcar(100.0, 25.0), &truth, s ^ 1);
            let obs: Vec<(f64, f64)> = stream.readings.iter().map(|r| (r.t, r.watts)).collect();
            let est = estimate_window(
                &truth,
                &obs,
                EstimatorConfig { update_period_s: 0.1, discard_s: 1.0, grid },
            );
            errs.push((est.window_s * 1000.0 - 25.0).abs());
            evals.push(est.evals as f64);
        }
        t.row(&[grid.to_string(), f(median(&errs), 2), f(mean(&evals), 0)]);
    }
    t
}

/// A3: measured update period vs polling cadence (V100: truth 20 ms).
pub fn poll_period_ablation(seed: u64) -> Table {
    let mut t = Table::new(
        "A3 — measured update period vs polling cadence (V100, truth 20 ms)",
        &["poll ms", "median update ms", "detected"],
    );
    let device = GpuDevice::new(find_model("V100 PCIe").unwrap(), 0, seed);
    for poll_ms in [1.0, 2.0, 5.0, 10.0, 20.0, 50.0] {
        let act = ActivitySignal::square_wave(0.2, 0.02, 0.5, 1.0, 280);
        let truth = device.synthesize(&act, 0.0, 6.5);
        let smi = NvidiaSmi::attach(device.clone(), DriverEpoch::Pre530, &truth, seed ^ 7);
        let log = smi.poll(PowerField::Draw, poll_ms / 1000.0, 0.3, 6.3);
        let periods = log.update_periods();
        if periods.len() < 5 {
            t.row(&[f(poll_ms, 0), "-".into(), "false".into()]);
        } else {
            t.row(&[f(poll_ms, 0), f(median(&periods) * 1000.0, 1), "true".into()]);
        }
    }
    t
}

/// A4: energy-counter designs vs PMD on the aliased A100 load.
pub fn energy_counter_ablation(seed: u64) -> Table {
    let mut t = Table::new(
        "A4 — NVML energy-counter designs (A100, aliased 100 ms load)",
        &["design", "energy err % vs truth"],
    );
    let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, seed);
    let act = ActivitySignal::square_wave(0.5, 0.1004, 0.5, 1.0, 60);
    let truth = device.synthesize(&act, 0.0, 7.0);
    let spec = PipelineSpec::boxcar(100.0, 25.0);
    let want = device.tolerance.apply(truth.energy_between(1.0, 6.0) / 5.0) * 5.0;
    for design in [CounterDesign::Continuous, CounterDesign::Windowed] {
        let c = run_counter(&device, spec, &truth, design);
        let e = c.energy_between_j(1.0, 6.0);
        t.row(&[format!("{design:?}"), f(100.0 * (e - want) / want, 2)]);
    }
    t
}

/// A5: good-practice-style measurement error under sample dropout.
pub fn fault_robustness_ablation(trials: usize, seed: u64) -> Table {
    let mut t = Table::new(
        "A5 — corrected measurement error under poll-sample dropout (RTX 3090)",
        &["dropout %", "mean err %", "std err %"],
    );
    let sensor = SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 };
    let device = GpuDevice::new(find_model("RTX 3090").unwrap(), 0, seed);
    let rig = MeasurementRig::new(device, DriverEpoch::Post530, PowerField::Instant, seed);
    for dropout in [0.0, 0.1, 0.3, 0.5] {
        let mut errs = Vec::new();
        for trial in 0..trials {
            let load = crate::bench::BenchmarkLoad::new(0.1, 1.0, 50);
            let act = load.build(0.75, 50, 0, 0.0);
            let t_end = act.t_end();
            let cap = rig.capture(&act, 0.0, t_end + 0.6, seed ^ trial as u64);
            let log = cap.smi.poll(PowerField::Instant, 0.02, 0.4, t_end + 0.4);
            let lossy = drop_samples(&log.series, dropout, seed ^ (trial as u64) << 4);
            let shifted = shift_earlier(&lossy, sensor.window_s / 2.0);
            let t_a = 0.75 + 0.4; // discard rise
            let p = mean_power(&shifted, t_a, t_end);
            let truth = cap.pmd_trace.energy_between(t_a, t_end) / (t_end - t_a);
            errs.push(100.0 * (p - truth) / truth);
        }
        t.row(&[f(dropout * 100.0, 0), f(mean(&errs), 2), f(std_dev(&errs), 2)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shift_ablation_monotone_trend() {
        let t = shift_count_ablation(6, 300);
        assert_eq!(t.rows.len(), 5);
        let std_at = |i: usize| t.rows[i][2].parse::<f64>().unwrap();
        // 8 shifts must beat 0 shifts decisively
        assert!(std_at(3) < std_at(0), "8 shifts {} !< 0 shifts {}", std_at(3), std_at(0));
    }

    #[test]
    fn grid_ablation_runs() {
        let t = grid_size_ablation(3, 301);
        assert_eq!(t.rows.len(), 6);
        // with a reasonable grid the median error is small
        let err32 = t.rows[4][1].parse::<f64>().unwrap();
        assert!(err32 < 8.0, "grid=32 err {err32}");
    }

    #[test]
    fn poll_ablation_detects_at_fast_cadence() {
        let t = poll_period_ablation(302);
        assert_eq!(t.rows[0][2], "true"); // 1 ms
        assert_eq!(t.rows[1][2], "true"); // 2 ms
        let err = (t.rows[1][1].parse::<f64>().unwrap() - 20.0).abs();
        assert!(err < 4.0);
    }

    #[test]
    fn counter_ablation_continuous_wins() {
        let t = energy_counter_ablation(303);
        let cont = t.rows[0][1].parse::<f64>().unwrap().abs();
        let wind = t.rows[1][1].parse::<f64>().unwrap().abs();
        assert!(cont < 2.0, "continuous {cont}");
        assert!(cont <= wind + 0.5, "continuous {cont} vs windowed {wind}");
    }

    #[test]
    fn fault_ablation_degrades_gracefully() {
        let t = fault_robustness_ablation(4, 304);
        let e0 = t.rows[0][1].parse::<f64>().unwrap();
        let e50 = t.rows[3][1].parse::<f64>().unwrap();
        // even 50% dropout moves the mean error by only a few points
        assert!((e0 - e50).abs() < 5.0, "{e0} vs {e50}");
    }
}
