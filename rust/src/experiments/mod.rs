//! One module per paper figure/table (DESIGN.md §5). Each exposes a
//! `run(...) -> <FigureResult>` returning structured data plus a
//! `render()`-able table, so the CLI, examples, tests and benches all share
//! the same code path that regenerates the paper's evaluation artefacts.

pub mod ablations;
pub mod common;
pub mod energy_cases;
pub mod fig01_motivation;
pub mod fig05_calibration;
pub mod fig06_update_period;
pub mod fig07_transient;
pub mod fig08_steady_state;
pub mod fig09_gradient_offset;
pub mod fig10_boxcar_alias;
pub mod fig11_reconstruction;
pub mod fig12_window_loss;
pub mod fig13_window_dist;
pub mod fig14_matrix;
pub mod fig15_case1;
pub mod fig16_case2;
pub mod fig17_case3;
pub mod fig18_evaluation;
pub mod fig19_gh200;
pub mod tables;
