//! Shared machinery for the §5.1 exploration (Figs. 15–17): energy
//! measurement error as a function of repetition count, with and without
//! the good-practice corrections, for the three averaging-window cases.

use crate::bench::BenchmarkLoad;
use crate::estimator::stats::{mean, pct_error, std_dev};
use crate::measure::energy::{mean_power, shift_earlier};
use crate::measure::{MeasurementRig, RepeatableLoad, SensorCharacterization};
use crate::rng::Rng;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};

/// Configuration of one case sweep.
#[derive(Debug, Clone)]
pub struct CaseConfig {
    pub model: &'static str,
    pub driver: DriverEpoch,
    pub field: PowerField,
    /// What the micro-benchmarks learned about this sensor.
    pub sensor: SensorCharacterization,
    /// Benchmark-load square-wave period, seconds.
    pub period_s: f64,
    /// Repetition counts to sweep.
    pub reps_list: Vec<usize>,
    /// Trials per repetition count (paper: 32).
    pub trials: usize,
    /// Controlled delays per run (paper Case 3: 0 / 4 / 8).
    pub shifts: usize,
    pub seed: u64,
}

/// Error statistics at one repetition count.
#[derive(Debug, Clone, Copy)]
pub struct RepsPoint {
    pub reps: usize,
    /// Raw integration over the kernel execution period.
    pub naive_mean_pct: f64,
    pub naive_std_pct: f64,
    /// With rise-time discard + boxcar shift applied.
    pub corrected_mean_pct: f64,
    pub corrected_std_pct: f64,
}

/// Run the sweep.
pub fn run_case(cfg: &CaseConfig) -> Vec<RepsPoint> {
    let device = GpuDevice::new(find_model(cfg.model).unwrap(), 0, cfg.seed);
    let rig = MeasurementRig::new(device, cfg.driver, cfg.field, cfg.seed);
    let poll_s = (cfg.sensor.update_s / 4.0).clamp(0.005, 0.02);
    let mut rng = Rng::new(cfg.seed ^ 0xCA5E);

    let mut out = Vec::with_capacity(cfg.reps_list.len());
    for &reps in &cfg.reps_list {
        let mut naive_errs = Vec::with_capacity(cfg.trials);
        let mut corr_errs = Vec::with_capacity(cfg.trials);
        for trial in 0..cfg.trials {
            // randomised 0-1 s delay between trials (paper)
            let t_start = 0.5 + rng.uniform();
            let load = BenchmarkLoad::new(cfg.period_s, 1.0, reps);
            let reps_per_shift = if cfg.shifts > 0 { (reps / cfg.shifts).max(1) } else { 0 };
            let act = load.build(t_start, reps, reps_per_shift, cfg.sensor.window_s);
            let t_end = act.t_end();
            let boot = cfg.seed ^ ((reps * 1000 + trial) as u64).wrapping_mul(0x9E37_79B9);
            let t_tail = cfg.sensor.window_s + 2.0 * cfg.sensor.update_s;
            let cap = rig.capture(&act, 0.0, t_end + t_tail + 0.3, boot);
            let log = cap.smi.poll(
                cfg.field,
                poll_s,
                t_start - 2.0 * cfg.sensor.window_s.max(cfg.sensor.update_s),
                t_end + t_tail,
            );

            let truth_between = |a: f64, b: f64| {
                cap.pmd_trace.energy_between(a, b) / (b - a)
            };

            // naive: integrate the raw readings over the kernel window
            let p_naive = mean_power(&log.series, t_start, t_end);
            naive_errs.push(pct_error(p_naive, truth_between(t_start, t_end)));

            // corrected: shift by the boxcar group delay, discard settle reps
            let shifted = shift_earlier(&log.series, cfg.sensor.window_s / 2.0);
            let settle = cfg.sensor.rise_s + cfg.sensor.window_s;
            let discard = ((settle / cfg.period_s).ceil() as usize).min(reps.saturating_sub(1));
            let t_a = t_start + discard as f64 * cfg.period_s;
            let p_corr = mean_power(&shifted, t_a, t_end);
            corr_errs.push(pct_error(p_corr, truth_between(t_a, t_end)));
        }
        out.push(RepsPoint {
            reps,
            naive_mean_pct: mean(&naive_errs),
            naive_std_pct: std_dev(&naive_errs),
            corrected_mean_pct: mean(&corr_errs),
            corrected_std_pct: std_dev(&corr_errs),
        });
    }
    out
}

/// Render a sweep as a table.
pub fn table(title: &str, points: &[RepsPoint]) -> crate::report::Table {
    use crate::report::f;
    let mut t = crate::report::Table::new(
        title,
        &["reps", "naive mean %", "naive std %", "corrected mean %", "corrected std %"],
    );
    for p in points {
        t.row(&[
            p.reps.to_string(),
            f(p.naive_mean_pct, 2),
            f(p.naive_std_pct, 2),
            f(p.corrected_mean_pct, 2),
            f(p.corrected_std_pct, 2),
        ]);
    }
    t
}

/// Default repetition sweep (paper-style doubling).
pub fn default_reps() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 32, 64]
}
