//! Fig. 14: the summary matrix — for every generation × driver version ×
//! field, the *measured* behaviour (rise class, update period, averaging
//! window), recovered purely by running the micro-benchmarks against the
//! emulated sensor, then compared against the encoded ground truth.
//!
//! This is the reproduction's central validation: the paper's methodology,
//! applied to our simulated fleet, must re-derive the table the paper
//! published.

use super::common::{measure_update_period, probe_transient, probe_window, TransientClass};
use crate::report::{f, Table};
use crate::sim::device::GpuDevice;
use crate::sim::profile::{
    sensor_pipeline, DriverEpoch, Generation, GpuModel, PipelineKind, PowerField, CATALOGUE,
};

/// One measured cell of the matrix.
#[derive(Debug, Clone)]
pub struct MatrixCell {
    pub generation: Generation,
    pub model: &'static str,
    pub driver: DriverEpoch,
    pub field: PowerField,
    /// Measured update period, ms (None = unsupported).
    pub update_ms: Option<f64>,
    /// Measured averaging window, ms (None = not boxcar / unsupported).
    pub window_ms: Option<f64>,
    /// Measured transient class.
    pub transient: Option<TransientClass>,
    /// Ground truth for comparison.
    pub truth_update_ms: Option<f64>,
    pub truth_window_ms: Option<f64>,
}

impl MatrixCell {
    /// Did the measurement recover the encoded ground truth?
    pub fn matches_truth(&self) -> bool {
        match (self.truth_update_ms, self.update_ms) {
            (None, None) => true,
            (Some(t), Some(m)) => {
                let update_ok = (m - t).abs() < t * 0.25 + 2.0;
                let window_ok = match (self.truth_window_ms, self.window_ms) {
                    (Some(tw), Some(mw)) => (mw - tw).abs() < tw * 0.4 + 6.0,
                    (None, _) => true, // RC/estimation: no boxcar window to recover
                    (Some(_), None) => false,
                };
                update_ok && window_ok
            }
            _ => false,
        }
    }
}

/// Representative model for a generation (first catalogue entry).
pub fn representative(gen: Generation) -> Option<&'static GpuModel> {
    CATALOGUE.iter().find(|m| m.generation == gen)
}

/// Measure one cell.
pub fn measure_cell(gen: Generation, driver: DriverEpoch, field: PowerField, seed: u64) -> Option<MatrixCell> {
    let model = representative(gen)?;
    let device = GpuDevice::new(model, 0, seed);
    let spec = sensor_pipeline(gen, field, driver);
    let (truth_update_ms, truth_window_ms) = match spec.kind {
        PipelineKind::Boxcar { window_ms } => (Some(spec.update_ms), Some(window_ms)),
        PipelineKind::RcFilter { .. } => (Some(spec.update_ms), None),
        // Estimation-based boards (Fermi 2.0 era): the 5 W-quantised
        // activity estimate barely moves under the probe wave, so the
        // cadence is unobservable — the paper likewise reports these as a
        // category of their own rather than with measured parameters.
        PipelineKind::Estimation | PipelineKind::Unsupported => (None, None),
    };

    let update = measure_update_period(&device, driver, field, seed ^ 0x14A);
    let transient = probe_transient(&device, driver, field, seed ^ 0x14B);
    // window estimation strategy depends on the transient class:
    //  * LogarithmicLag (RC distortion): there is no boxcar window;
    //  * LinearLag: the window is much longer than the update period and
    //    outside the aliasing probe's scan range — but a step through a
    //    w-wide boxcar rises 10→90% in exactly 0.8·w, so the Fig. 7 probe
    //    already measured it;
    //  * otherwise: the §4.3 aliased-square-wave estimator.
    let window = match (update, &transient) {
        (Some(u), Some(tr)) => match tr.class {
            TransientClass::LogarithmicLag => None,
            TransientClass::LinearLag => Some(tr.smi_rise_s / 0.8 * 1000.0),
            _ => probe_window(&device, driver, field, u, 0.75, seed ^ 0x14C).map(|w| w * 1000.0),
        },
        _ => None,
    };
    Some(MatrixCell {
        generation: gen,
        model: model.name,
        driver,
        field,
        update_ms: update.map(|u| u * 1000.0),
        window_ms: window,
        transient: transient.map(|r| r.class),
        truth_update_ms,
        truth_window_ms,
    })
}

/// Build the full matrix (all generations × drivers for `power.draw`, plus
/// the post-530 average/instant fields).
pub fn run(seed: u64) -> Vec<MatrixCell> {
    let mut cells = Vec::new();
    for gen in Generation::ALL {
        if gen == Generation::GraceHopper {
            continue; // separate §6 experiment (fig19)
        }
        for driver in DriverEpoch::ALL {
            let fields: &[PowerField] = match driver {
                DriverEpoch::Post530 => &PowerField::ALL,
                _ => &[PowerField::Draw],
            };
            for &field in fields {
                if let Some(c) = measure_cell(gen, driver, field, seed) {
                    cells.push(c);
                }
            }
        }
    }
    cells
}

/// Tabulate.
pub fn table(cells: &[MatrixCell]) -> Table {
    let mut t = Table::new(
        "Fig. 14 — measured sensor-pipeline matrix (vs encoded truth)",
        &["generation", "driver", "field", "update ms", "window ms", "transient", "matches"],
    );
    for c in cells {
        t.row(&[
            c.generation.name().into(),
            c.driver.name().into(),
            c.field.query_name().into(),
            c.update_ms.map_or("N/A".into(), |v| f(v, 0)),
            c.window_ms.map_or("-".into(), |v| f(v, 0)),
            c.transient.map_or("-".into(), |v| format!("{v:?}")),
            c.matches_truth().to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_cells_recover_ground_truth() {
        // spot-check the paper's headline cells instead of the full (slow) matrix
        let cases = [
            (Generation::AmpereGa100, DriverEpoch::Post530, PowerField::Instant),
            (Generation::Volta, DriverEpoch::Pre530, PowerField::Draw),
            (Generation::Turing, DriverEpoch::V530, PowerField::Draw),
            (Generation::Hopper, DriverEpoch::Post530, PowerField::Instant),
        ];
        for (gen, driver, field) in cases {
            let c = measure_cell(gen, driver, field, 140).unwrap();
            assert!(c.matches_truth(), "{:?}/{:?}/{:?}: {:?}", gen, driver, field, c);
        }
    }

    #[test]
    fn unsupported_cells_report_na() {
        let c = measure_cell(Generation::Fermi1, DriverEpoch::Pre530, PowerField::Draw, 141).unwrap();
        assert!(c.update_ms.is_none());
        assert!(c.matches_truth());
    }
}
