//! Fig. 13: distribution of the window estimate over repeated runs at the
//! paper's six period fractions (2/3, 3/4, 4/5, 6/5, 5/4, 4/3 of the
//! update period), shown as violin summaries; std devs of a few ms.

use super::common::probe_window;
use crate::estimator::stats::{std_dev, violin, ViolinSummary};
use crate::report::{f, Table};
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, sensor_pipeline, DriverEpoch, PipelineKind, PowerField};

/// The paper's six load-period fractions.
pub const FRACTIONS: [f64; 6] = [2.0 / 3.0, 0.75, 0.8, 1.2, 1.25, 4.0 / 3.0];

/// Distribution result for one GPU.
#[derive(Debug, Clone)]
pub struct WindowDistResult {
    pub model: &'static str,
    /// All estimates, ms.
    pub estimates_ms: Vec<f64>,
    pub violin: ViolinSummary,
    pub std_ms: f64,
    pub true_window_ms: f64,
}

/// Run `runs_per_fraction` estimates per fraction on one model.
pub fn run_one(model: &str, runs_per_fraction: usize, seed: u64) -> WindowDistResult {
    let m = find_model(model).unwrap();
    let (driver, field) = (DriverEpoch::Post530, PowerField::Instant);
    let spec = sensor_pipeline(m.generation, field, driver);
    let update_s = spec.update_ms / 1000.0;
    let true_window_ms = match spec.kind {
        PipelineKind::Boxcar { window_ms } => window_ms,
        _ => f64::NAN,
    };
    let mut estimates_ms = Vec::new();
    for (fi, &frac) in FRACTIONS.iter().enumerate() {
        for run in 0..runs_per_fraction {
            let s = seed ^ ((fi * 1000 + run) as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let device = GpuDevice::new(m, 0, s);
            if let Some(w) = probe_window(&device, driver, field, update_s, frac, s ^ 0xD15) {
                estimates_ms.push(w * 1000.0);
            }
        }
    }
    WindowDistResult {
        model: m.name,
        violin: violin(&estimates_ms),
        std_ms: std_dev(&estimates_ms),
        estimates_ms,
        true_window_ms,
    }
}

/// The paper's three GPUs (reduced run count is fine for smoke use).
pub fn run(runs_per_fraction: usize, seed: u64) -> Vec<WindowDistResult> {
    ["GTX 1080 Ti", "A100 PCIe-40G", "RTX 3090"]
        .iter()
        .map(|m| run_one(m, runs_per_fraction, seed))
        .collect()
}

/// Tabulate violin summaries.
pub fn table(results: &[WindowDistResult]) -> Table {
    let mut t = Table::new(
        "Fig. 13 — window-estimate distribution (violin summary, ms)",
        &["GPU", "true", "median", "q1", "q3", "lo-adj", "hi-adj", "std", "n"],
    );
    for r in results {
        t.row(&[
            r.model.into(),
            f(r.true_window_ms, 0),
            f(r.violin.median, 1),
            f(r.violin.q1, 1),
            f(r.violin.q3, 1),
            f(r.violin.lo_adjacent, 1),
            f(r.violin.hi_adjacent, 1),
            f(r.std_ms, 1),
            r.violin.n.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn medians_near_truth_with_small_spread() {
        // 4 runs/fraction (24 estimates per GPU) keeps the test quick
        for r in run(4, 90) {
            assert!(
                (r.violin.median - r.true_window_ms).abs() < r.true_window_ms.max(10.0) * 0.35,
                "{}: median {} vs true {}",
                r.model,
                r.violin.median,
                r.true_window_ms
            );
            // paper std devs are 1.2-3.3 ms; allow slack for reduced runs
            assert!(r.std_ms < 12.0, "{}: std {}", r.model, r.std_ms);
        }
    }
}
