//! Fig. 18: the headline evaluation — energy error of the naive method vs
//! the good practice for the nine Table 2 workloads under all three
//! averaging-window cases. Paper: naive up to ~70% error, good practice
//! ≈ 5% across the board; average reduction 34.38%, per-case std ≈ 0.25%.

use crate::bench::workloads::WORKLOADS;
use crate::estimator::stats::{mean, std_dev};
use crate::measure::{
    good_practice::measure_good_practice, naive::measure_naive, GoodPracticeConfig,
    MeasurementRig, SensorCharacterization,
};
use crate::report::{f, Table};
use crate::sim::device::GpuDevice;
use crate::sim::profile::{find_model, DriverEpoch, PowerField};

/// The three cases (model, driver, field, sensor knowledge).
#[derive(Debug, Clone, Copy)]
pub struct Case {
    pub label: &'static str,
    pub model: &'static str,
    pub driver: DriverEpoch,
    pub field: PowerField,
    pub sensor: SensorCharacterization,
}

/// The paper's three case setups.
pub fn cases() -> [Case; 3] {
    [
        Case {
            label: "100/100 (RTX 3090 instant)",
            model: "RTX 3090",
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            sensor: SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 },
        },
        Case {
            label: "1000/100 (RTX 3090 draw)",
            model: "RTX 3090",
            driver: DriverEpoch::Post530,
            field: PowerField::Draw,
            sensor: SensorCharacterization { update_s: 0.1, window_s: 1.0, rise_s: 0.25 },
        },
        Case {
            label: "25/100 (A100 instant)",
            model: "A100 PCIe-40G",
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            sensor: SensorCharacterization { update_s: 0.1, window_s: 0.025, rise_s: 0.1 },
        },
    ]
}

/// Per-workload outcome in one case.
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    pub workload: &'static str,
    pub naive_pct: f64,
    pub good_pct: f64,
}

/// Per-case aggregate.
#[derive(Debug, Clone)]
pub struct CaseOutcome {
    pub case: Case,
    pub workloads: Vec<WorkloadOutcome>,
    pub naive_mean_abs: f64,
    pub good_mean_abs: f64,
    pub good_std: f64,
}

/// Run one case over all nine workloads.
pub fn run_one(case: Case, cfg: &GoodPracticeConfig, seed: u64) -> CaseOutcome {
    let mut outcomes = Vec::with_capacity(WORKLOADS.len());
    for (wi, wl) in WORKLOADS.iter().enumerate() {
        let device = GpuDevice::new(find_model(case.model).unwrap(), 0, seed ^ wi as u64);
        let rig = MeasurementRig::new(device, case.driver, case.field, seed ^ (wi as u64) << 8);
        let naive = measure_naive(&rig, wl, cfg.poll_period_s, seed ^ 0xE18);
        let good = measure_good_practice(&rig, wl, &case.sensor, cfg);
        outcomes.push(WorkloadOutcome {
            workload: wl.name,
            naive_pct: naive.pct_error,
            good_pct: good.mean_pct_error,
        });
    }
    let naive_abs: Vec<f64> = outcomes.iter().map(|o| o.naive_pct.abs()).collect();
    let good_abs: Vec<f64> = outcomes.iter().map(|o| o.good_pct.abs()).collect();
    let good_raw: Vec<f64> = outcomes.iter().map(|o| o.good_pct).collect();
    CaseOutcome {
        case,
        naive_mean_abs: mean(&naive_abs),
        good_mean_abs: mean(&good_abs),
        good_std: std_dev(&good_raw),
        workloads: outcomes,
    }
}

/// Run all three cases.
pub fn run(cfg: &GoodPracticeConfig, seed: u64) -> Vec<CaseOutcome> {
    cases().into_iter().map(|c| run_one(c, cfg, seed)).collect()
}

/// Tabulate one case.
pub fn table(outcome: &CaseOutcome) -> Table {
    let mut t = Table::new(
        format!("Fig. 18 — naive vs good practice, case {}", outcome.case.label),
        &["workload", "naive %err", "good practice %err"],
    );
    for w in &outcome.workloads {
        t.row(&[w.workload.into(), f(w.naive_pct, 2), f(w.good_pct, 2)]);
    }
    t.row(&[
        "mean |err|".into(),
        f(outcome.naive_mean_abs, 2),
        f(outcome.good_mean_abs, 2),
    ]);
    t.row(&["std (good)".into(), "-".into(), f(outcome.good_std, 2)]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> GoodPracticeConfig {
        GoodPracticeConfig { trials: 2, min_reps: 16, min_runtime_s: 2.0, ..Default::default() }
    }

    #[test]
    fn good_practice_beats_naive_in_every_case() {
        for outcome in run(&quick_cfg(), 180) {
            assert!(
                outcome.good_mean_abs < outcome.naive_mean_abs,
                "case {}: good {:.2}% !< naive {:.2}%",
                outcome.case.label,
                outcome.good_mean_abs,
                outcome.naive_mean_abs
            );
        }
    }

    #[test]
    fn good_practice_error_is_single_digit() {
        for outcome in run(&quick_cfg(), 181) {
            assert!(
                outcome.good_mean_abs < 10.0,
                "case {}: {:.2}%",
                outcome.case.label,
                outcome.good_mean_abs
            );
        }
    }

    #[test]
    fn good_practice_is_stable_across_workloads() {
        // quick_cfg uses 2 trials / 16 reps / 2 s (vs the paper's 4/32/5 s),
        // so the spread bound is looser here; the full-config CLI run
        // reproduces the paper's sub-percent std (EXPERIMENTS.md)
        for outcome in run(&quick_cfg(), 182) {
            assert!(
                outcome.good_std < 6.0,
                "case {}: std {:.2}%",
                outcome.case.label,
                outcome.good_std
            );
        }
    }
}
