//! Shared characterisation routines used by the figure experiments: these
//! are the paper's three micro-benchmarks (§4.1–4.3) packaged as functions
//! that see *only* what a real user of nvidia-smi would see (polled
//! readings), never the simulator's hidden profile.

use crate::estimator::boxcar::{estimate_window, EstimatorConfig};
use crate::estimator::stats::median;
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{DriverEpoch, PowerField};
use crate::smi::NvidiaSmi;

/// §4.1: measure the power update period by polling fast during a
/// varying load and taking the median time between value changes.
pub fn measure_update_period(device: &GpuDevice, driver: DriverEpoch, field: PowerField, seed: u64) -> Option<f64> {
    // 20 ms square wave guarantees the value changes at almost every update
    let act = ActivitySignal::square_wave(0.2, 0.02, 0.5, 1.0, 220);
    let truth = device.synthesize(&act, 0.0, 5.0);
    let smi = NvidiaSmi::attach(device.clone(), driver, &truth, seed);
    let log = smi.poll(field, 0.002, 0.3, 4.8);
    let periods = log.update_periods();
    if periods.len() < 5 {
        return None;
    }
    Some(median(&periods))
}

/// Transient-response classes observed in Fig. 7.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransientClass {
    /// Case 1: actual rise near-instant; smi follows at the next update.
    InstantActualInstantSmi,
    /// Case 2: actual power ramps over hundreds of ms; smi tracks it.
    SlowActualTrackedSmi,
    /// Case 3: smi lags with ~linear growth over 1 s (1 s average window).
    LinearLag,
    /// Case 4: logarithmic growth (RC distortion, Kepler/Maxwell).
    LogarithmicLag,
}

/// Result of the §4.2 transient probe.
#[derive(Debug, Clone, Copy)]
pub struct TransientResult {
    pub class: TransientClass,
    /// 10→90% rise time of the *actual* (PMD-visible) power, seconds.
    pub actual_rise_s: f64,
    /// 10→90% rise time of the smi-reported power, seconds.
    pub smi_rise_s: f64,
}

/// §4.2: single 6 s step; classify the smi response.
pub fn probe_transient(
    device: &GpuDevice,
    driver: DriverEpoch,
    field: PowerField,
    seed: u64,
) -> Option<TransientResult> {
    let t_step = 1.0;
    let act = ActivitySignal::burst(t_step, 6.0, 1.0);
    let truth = device.synthesize(&act, 0.0, 8.0);
    let smi = NvidiaSmi::attach(device.clone(), driver, &truth, seed);
    let log = smi.poll(field, 0.01, 0.0, 8.0);
    if log.series.points.len() < 20 {
        return None;
    }

    // actual rise time from the truth trace (smoothed by a 10 ms window)
    let prefix = truth.prefix_sums();
    let smooth = |t: f64| truth.window_mean_with(&prefix, t, 0.01);
    let p_lo = smooth(0.9);
    let p_hi = smooth(6.5);
    let rise = |f: &dyn Fn(f64) -> f64| -> f64 {
        let p10 = p_lo + 0.1 * (p_hi - p_lo);
        let p90 = p_lo + 0.9 * (p_hi - p_lo);
        let mut t10 = None;
        let mut t90 = None;
        let mut t = t_step - 0.05;
        while t < 7.0 {
            let p = f(t);
            if t10.is_none() && p >= p10 {
                t10 = Some(t);
            }
            if p >= p90 {
                t90 = Some(t);
                break;
            }
            t += 0.005;
        }
        match (t10, t90) {
            (Some(a), Some(b)) => b - a,
            _ => f64::NAN,
        }
    };
    let actual_rise_s = rise(&smooth);

    // smi rise time from the polled log (normalise against its own levels)
    let s_lo = {
        let pre: Vec<f64> =
            log.series.points.iter().filter(|p| p.0 < t_step - 0.1).map(|p| p.1).collect();
        median(&pre)
    };
    let s_hi = {
        let post: Vec<f64> =
            log.series.points.iter().filter(|p| p.0 > 4.0 && p.0 < 6.5).map(|p| p.1).collect();
        median(&post)
    };
    let smi_at = |t: f64| -> f64 {
        log.series
            .points
            .iter()
            .take_while(|p| p.0 <= t)
            .last()
            .map(|p| p.1)
            .unwrap_or(s_lo)
    };
    if (s_hi - s_lo).abs() < 1e-9 {
        return None; // degenerate: sensor never moved
    }
    // rescale the smi signal onto the actual power axis and reuse the riser
    let smi_rise_s = rise(&|t| p_lo + (smi_at(t) - s_lo) / (s_hi - s_lo) * (p_hi - p_lo));

    // classification thresholds (Fig. 7's four shapes)
    let class = if smi_rise_s > 0.6 {
        TransientClass::LinearLag
    } else if smi_rise_s > 0.12 && actual_rise_s < 0.5 * smi_rise_s {
        TransientClass::LogarithmicLag
    } else if actual_rise_s > 0.15 {
        TransientClass::SlowActualTrackedSmi
    } else {
        TransientClass::InstantActualInstantSmi
    };
    Some(TransientResult { class, actual_rise_s, smi_rise_s })
}

/// §4.3: estimate the boxcar averaging window with the aliased square-wave
/// method. `period_frac` is the load period as a fraction of the update
/// period (the paper uses 2/3, 3/4, 4/5, 6/5, 5/4, 4/3).
pub fn probe_window(
    device: &GpuDevice,
    driver: DriverEpoch,
    field: PowerField,
    update_s: f64,
    period_frac: f64,
    seed: u64,
) -> Option<f64> {
    let period_s = update_s * period_frac;
    let cycles = (8.5 / period_s) as usize;
    let act = ActivitySignal::square_wave(0.3, period_s, 0.5, 1.0, cycles);
    let truth = device.synthesize(&act, 0.0, 9.0);
    let smi = NvidiaSmi::attach(device.clone(), driver, &truth, seed);
    let stream = smi.stream(field);
    if stream.readings.len() < 16 {
        return None;
    }
    let observed: Vec<(f64, f64)> = stream.readings.iter().map(|r| (r.t, r.watts)).collect();
    let est = estimate_window(
        &truth,
        &observed,
        EstimatorConfig { update_period_s: update_s, ..Default::default() },
    );
    Some(est.window_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::profile::find_model;

    fn dev(name: &str, seed: u64) -> GpuDevice {
        GpuDevice::new(find_model(name).unwrap(), 0, seed)
    }

    #[test]
    fn update_period_v100_is_20ms() {
        let p = measure_update_period(&dev("V100 PCIe", 1), DriverEpoch::Pre530, PowerField::Draw, 2)
            .unwrap();
        assert!((p - 0.020).abs() < 0.004, "p={p}");
    }

    #[test]
    fn update_period_a100_is_100ms() {
        let p =
            measure_update_period(&dev("A100 PCIe-40G", 1), DriverEpoch::Pre530, PowerField::Draw, 2)
                .unwrap();
        assert!((p - 0.100).abs() < 0.015, "p={p}");
    }

    #[test]
    fn update_period_unsupported_is_none() {
        let p = measure_update_period(&dev("C2050", 1), DriverEpoch::Pre530, PowerField::Draw, 2);
        assert!(p.is_none());
    }

    #[test]
    fn transient_h100_instant_is_case1() {
        let r = probe_transient(&dev("H100", 3), DriverEpoch::Post530, PowerField::Instant, 4).unwrap();
        assert_eq!(r.class, TransientClass::InstantActualInstantSmi, "{r:?}");
    }

    #[test]
    fn transient_3090_tracks_slow_board_rise() {
        let r = probe_transient(&dev("RTX 3090", 3), DriverEpoch::V530, PowerField::Draw, 4).unwrap();
        assert_eq!(r.class, TransientClass::SlowActualTrackedSmi, "{r:?}");
        assert!(r.actual_rise_s > 0.15 && r.actual_rise_s < 0.45, "{r:?}");
    }

    #[test]
    fn transient_ampere_pre530_is_linear_lag() {
        let r = probe_transient(&dev("RTX A6000", 3), DriverEpoch::Pre530, PowerField::Draw, 4).unwrap();
        assert_eq!(r.class, TransientClass::LinearLag, "{r:?}");
        assert!(r.smi_rise_s > 0.6, "1 s window rises slowly: {r:?}");
    }

    #[test]
    fn transient_kepler_is_logarithmic() {
        let r = probe_transient(&dev("Tesla K40", 3), DriverEpoch::Pre530, PowerField::Draw, 4).unwrap();
        assert_eq!(r.class, TransientClass::LogarithmicLag, "{r:?}");
    }

    #[test]
    fn window_probe_recovers_a100() {
        let w = probe_window(
            &dev("A100 PCIe-40G", 5),
            DriverEpoch::Post530,
            PowerField::Instant,
            0.1,
            0.75,
            6,
        )
        .unwrap();
        assert!((w - 0.025).abs() < 0.008, "w={w}");
    }
}
