//! Tables 1 and 2 of the paper, regenerated from the catalogue and the
//! workload suite.

use crate::bench::workloads::WORKLOADS;
use crate::report::Table;
use crate::sim::profile::{total_cards, ProductLine, CATALOGUE};

/// Table 1: the tested-GPU catalogue.
pub fn table1() -> Table {
    let mut t = Table::new(
        format!("Table 1 — tested GPUs ({} cards total)", total_cards()),
        &["architecture", "model", "line", "form", "TDP W", "# tested"],
    );
    for m in CATALOGUE {
        let line = match m.line {
            ProductLine::Tesla => "Tesla",
            ProductLine::Quadro => "Quadro",
            ProductLine::GeForce => "GeForce",
            ProductLine::Instinct => "Instinct",
        };
        t.row(&[
            m.generation.name().into(),
            m.name.into(),
            line.into(),
            format!("{:?}", m.form),
            format!("{:.0}", m.tdp_w),
            m.tested_count.to_string(),
        ]);
    }
    t
}

/// Table 2: the benchmark suite.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — selected benchmarks",
        &["source", "benchmark", "application", "iteration ms"],
    );
    for w in WORKLOADS {
        t.row(&[
            w.source.into(),
            w.name.into(),
            w.application.into(),
            format!("{:.1}", w.iteration_s() * 1000.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_every_model() {
        let t = table1();
        assert_eq!(t.rows.len(), CATALOGUE.len());
        assert!(t.title.contains("cards total"));
    }

    #[test]
    fn table2_lists_nine_benchmarks() {
        assert_eq!(table2().rows.len(), 9);
    }
}
