//! Fig. 16 — Case 2: averaging window (1 s) longer than the update period
//! (100 ms): the default on Ampere/Ada/Hopper. Convergence needs more
//! repetitions; discarding the initial 1250 ms (250 ms rise + 1 s average)
//! restores Case-1-like accuracy.

use super::energy_cases::{default_reps, run_case, CaseConfig, RepsPoint};
use crate::measure::SensorCharacterization;
use crate::report::Table;
use crate::sim::profile::{DriverEpoch, PowerField};

/// Sensor knowledge: RTX 3090 `power.draw` post-530 (1 s window).
pub fn sensor() -> SensorCharacterization {
    SensorCharacterization { update_s: 0.1, window_s: 1.0, rise_s: 0.25 }
}

/// Load periods: 25%, 100%, 800% of the update period.
pub const PERIODS_S: [f64; 3] = [0.025, 0.1, 0.8];

/// Run one load period.
pub fn run_period(period_s: f64, trials: usize, seed: u64) -> Vec<RepsPoint> {
    run_case(&CaseConfig {
        model: "RTX 3090",
        driver: DriverEpoch::Post530,
        field: PowerField::Draw, // 1 s window
        sensor: sensor(),
        period_s,
        reps_list: default_reps(),
        trials,
        shifts: 0,
        seed,
    })
}

/// Run all periods.
pub fn run(trials: usize, seed: u64) -> Vec<(f64, Vec<RepsPoint>)> {
    PERIODS_S.iter().map(|&p| (p, run_period(p, trials, seed))).collect()
}

/// Tabulate.
pub fn tables(results: &[(f64, Vec<RepsPoint>)]) -> Vec<Table> {
    results
        .iter()
        .map(|(p, pts)| {
            super::energy_cases::table(
                &format!("Fig. 16 — Case 2 (1000/100 ms), load period {:.0} ms", p * 1000.0),
                pts,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slower_convergence_than_case1() {
        // at low repetition counts the 1 s ramp-up biases the naive reading
        // down much harder than in Case 1
        let c2 = run_period(0.1, 6, 160);
        let c1 = super::super::fig15_case1::run_period(0.1, 6, 160);
        assert!(
            c2[1].naive_mean_pct < c1[1].naive_mean_pct - 3.0,
            "case2 {} should underestimate more than case1 {}",
            c2[1].naive_mean_pct,
            c1[1].naive_mean_pct
        );
    }

    #[test]
    fn discard_restores_accuracy() {
        let pts = run_period(0.1, 6, 161);
        let last = pts.last().unwrap();
        assert!(
            last.corrected_mean_pct.abs() < 10.0,
            "corrected error {}",
            last.corrected_mean_pct
        );
        assert!(last.corrected_std_pct < 3.0, "corrected std {}", last.corrected_std_pct);
    }
}
