//! The paper's micro-benchmark suite (§3.4): a precisely controllable
//! square-wave GPU load.
//!
//! High state: the AOT-compiled Pallas FMA-chain kernel executed via PJRT
//! (`runtime::ArtifactRuntime::fma_chain`); duration is controlled through
//! the chain length after a linear-regression calibration (Fig. 5,
//! [`calibrate`]). Low state: a timed sleep. Amplitude: fraction of SMs
//! active (block count over SM count in the paper; the simulator's `util`).

pub mod calibrate;
pub mod replay;
pub mod workloads;

pub use calibrate::{calibrate, Calibration};
pub use replay::{parse_trace_csv, production_trace, to_trace_csv, ReplayLoad};
pub use workloads::{workload_by_name, Workload, WORKLOADS};

use crate::sim::activity::ActivitySignal;

/// Specification of one benchmark-load run.
#[derive(Debug, Clone, Copy)]
pub struct BenchmarkLoad {
    /// Square-wave period, seconds.
    pub period_s: f64,
    /// Fraction of the period spent in the high state.
    pub duty: f64,
    /// Fraction of SMs active during the high state (amplitude knob).
    pub sm_fraction: f64,
    /// Number of periods.
    pub cycles: usize,
    /// Start time, seconds.
    pub t_start: f64,
}

impl BenchmarkLoad {
    /// A standard 50%-duty load.
    pub fn new(period_s: f64, sm_fraction: f64, cycles: usize) -> Self {
        BenchmarkLoad { period_s, duty: 0.5, sm_fraction, cycles, t_start: 0.5 }
    }

    /// The activity signal this load induces on the device.
    pub fn activity(&self) -> ActivitySignal {
        ActivitySignal::square_wave(self.t_start, self.period_s, self.duty, self.sm_fraction, self.cycles)
    }

    /// Activity with extra *controlled delays*: after every
    /// `reps_per_shift` cycles, insert a `shift_s` pause (the paper's
    /// Case-3 phase-shifting strategy, §5.1).
    pub fn activity_with_shifts(&self, reps_per_shift: usize, shift_s: f64) -> ActivitySignal {
        let mut act = ActivitySignal::idle();
        let mut t = self.t_start;
        for k in 0..self.cycles {
            act.push(t, self.period_s * self.duty, self.sm_fraction);
            t += self.period_s;
            if reps_per_shift > 0 && (k + 1) % reps_per_shift == 0 && k + 1 < self.cycles {
                t += shift_s;
            }
        }
        act
    }

    /// Total wall time of the load.
    pub fn duration_s(&self) -> f64 {
        self.period_s * self.cycles as f64
    }

    /// End time.
    pub fn t_end(&self) -> f64 {
        self.t_start + self.duration_s()
    }

    /// The chain length (`niter`) the calibrated kernel needs for the high
    /// state of this load.
    pub fn niter_for(&self, cal: &Calibration) -> i32 {
        cal.niter_for_ms(self.period_s * self.duty * 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activity_matches_spec() {
        let b = BenchmarkLoad::new(0.1, 0.8, 10);
        let a = b.activity();
        assert_eq!(a.segments.len(), 10);
        assert!((a.busy_time() - 0.5).abs() < 1e-9);
        assert_eq!(a.segments[0].util, 0.8);
    }

    #[test]
    fn shifts_insert_pauses() {
        let b = BenchmarkLoad::new(0.1, 1.0, 8);
        let plain = b.activity();
        let shifted = b.activity_with_shifts(2, 0.025);
        // 3 shifts inserted (after cycles 2, 4, 6)
        let extra = shifted.t_end() - plain.t_end();
        assert!((extra - 3.0 * 0.025).abs() < 1e-9, "extra={extra}");
        assert_eq!(shifted.segments.len(), plain.segments.len());
    }

    #[test]
    fn zero_shift_equals_plain() {
        let b = BenchmarkLoad::new(0.05, 0.5, 5);
        let a = b.activity_with_shifts(0, 0.01);
        let p = b.activity();
        assert_eq!(a.segments.len(), p.segments.len());
        assert!((a.t_end() - p.t_end()).abs() < 1e-12);
    }
}
