//! The nine real-workload signatures (Table 2): CUBLAS, CUFFT, nvJPEG,
//! Stereo Disparity, Black-Scholes, Quasi-random Generation, ResNet-50,
//! RetinaNet, BERT.
//!
//! Fig. 18 evaluates *measurement methods*, not workloads; what matters is
//! a diverse set of realistic power shapes. Each signature is a repeating
//! phase pattern (utilisation, duration) capturing the workload's duty
//! structure: dense GEMM plateaus (CUBLAS/BERT), bursty kernels with
//! host-side gaps (nvJPEG), alternating compute/memory phases (CUFFT),
//! iteration-structured training/inference loops (ResNet/RetinaNet).

use crate::sim::activity::ActivitySignal;

/// One phase of a workload's repeating pattern.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    /// SM utilisation fraction during the phase.
    pub util: f64,
    /// Phase duration, seconds.
    pub duration_s: f64,
}

/// A named workload signature.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub application: &'static str,
    pub source: &'static str,
    /// The repeating phase pattern (one "iteration" of the workload).
    pub pattern: &'static [Phase],
}

impl Workload {
    /// Duration of one iteration.
    pub fn iteration_s(&self) -> f64 {
        self.pattern.iter().map(|p| p.duration_s).sum()
    }

    /// Activity signal for `reps` iterations starting at `t_start`.
    pub fn activity(&self, t_start: f64, reps: usize) -> ActivitySignal {
        let mut act = ActivitySignal::idle();
        let mut t = t_start;
        for _ in 0..reps {
            for ph in self.pattern {
                if ph.util > 0.0 {
                    act.push(t, ph.duration_s, ph.util);
                }
                t += ph.duration_s;
            }
        }
        act
    }

    /// Activity with controlled delays after every `reps_per_shift`
    /// iterations (good-practice Case 3).
    pub fn activity_with_shifts(
        &self,
        t_start: f64,
        reps: usize,
        reps_per_shift: usize,
        shift_s: f64,
    ) -> ActivitySignal {
        let mut act = ActivitySignal::idle();
        let mut t = t_start;
        for k in 0..reps {
            for ph in self.pattern {
                if ph.util > 0.0 {
                    act.push(t, ph.duration_s, ph.util);
                }
                t += ph.duration_s;
            }
            if reps_per_shift > 0 && (k + 1) % reps_per_shift == 0 && k + 1 < reps {
                t += shift_s;
            }
        }
        act
    }
}

/// Table 2: the nine selected benchmarks.
pub const WORKLOADS: &[Workload] = &[
    Workload {
        name: "cublas",
        application: "Linear Algebra (GEMM)",
        source: "NV Library",
        // long dense plateaus at near-full utilisation
        pattern: &[Phase { util: 0.97, duration_s: 0.085 }, Phase { util: 0.0, duration_s: 0.006 }],
    },
    Workload {
        name: "cufft",
        application: "Signal Processing",
        source: "NV Library",
        // alternating compute / memory-bound stages
        pattern: &[
            Phase { util: 0.85, duration_s: 0.022 },
            Phase { util: 0.45, duration_s: 0.018 },
            Phase { util: 0.0, duration_s: 0.004 },
        ],
    },
    Workload {
        name: "nvjpeg",
        application: "Image Compression",
        source: "NV Library",
        // short bursts with host-side gaps
        pattern: &[Phase { util: 0.65, duration_s: 0.011 }, Phase { util: 0.0, duration_s: 0.013 }],
    },
    Workload {
        name: "stereo_disparity",
        application: "Computer Vision",
        source: "Domain Specific",
        pattern: &[Phase { util: 0.78, duration_s: 0.032 }, Phase { util: 0.0, duration_s: 0.009 }],
    },
    Workload {
        name: "black_scholes",
        application: "Computational Finance",
        source: "Domain Specific",
        // memory-bandwidth-bound: moderate utilisation, very regular
        pattern: &[Phase { util: 0.60, duration_s: 0.046 }, Phase { util: 0.0, duration_s: 0.005 }],
    },
    Workload {
        name: "quasirandom",
        application: "Monte Carlo",
        source: "Domain Specific",
        pattern: &[Phase { util: 0.88, duration_s: 0.017 }, Phase { util: 0.0, duration_s: 0.007 }],
    },
    Workload {
        name: "resnet50",
        application: "Image Classification",
        source: "MLPerf",
        // per-batch loop: fwd (high), bwd (higher), optimizer + dataloader dip
        pattern: &[
            Phase { util: 0.82, duration_s: 0.035 },
            Phase { util: 0.95, duration_s: 0.058 },
            Phase { util: 0.35, duration_s: 0.012 },
            Phase { util: 0.0, duration_s: 0.008 },
        ],
    },
    Workload {
        name: "retinanet",
        application: "Object Detection",
        source: "MLPerf",
        pattern: &[
            Phase { util: 0.88, duration_s: 0.064 },
            Phase { util: 0.55, duration_s: 0.021 },
            Phase { util: 0.0, duration_s: 0.011 },
        ],
    },
    Workload {
        name: "bert",
        application: "Natural Language Processing",
        source: "MLPerf",
        // large attention GEMMs: sustained near-TDP with brief host sync
        pattern: &[Phase { util: 0.96, duration_s: 0.124 }, Phase { util: 0.0, duration_s: 0.009 }],
    },
];

/// Find a workload by name.
pub fn workload_by_name(name: &str) -> Option<&'static Workload> {
    WORKLOADS.iter().find(|w| w.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_workloads() {
        assert_eq!(WORKLOADS.len(), 9);
    }

    #[test]
    fn iteration_durations_positive_and_varied() {
        let durs: Vec<f64> = WORKLOADS.iter().map(|w| w.iteration_s()).collect();
        assert!(durs.iter().all(|&d| d > 0.005));
        let min = durs.iter().cloned().fold(f64::MAX, f64::min);
        let max = durs.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max / min > 3.0, "range of execution times (paper §5.2)");
    }

    #[test]
    fn activity_repeats_pattern() {
        let w = workload_by_name("resnet50").unwrap();
        let act = w.activity(1.0, 10);
        // 3 busy phases per iteration
        assert_eq!(act.segments.len(), 30);
        assert!((act.t_start() - 1.0).abs() < 1e-12);
        let expect_end = 1.0 + 10.0 * w.iteration_s();
        assert!((act.t_end() - expect_end).abs() < 0.02);
    }

    #[test]
    fn shifts_extend_duration() {
        let w = workload_by_name("bert").unwrap();
        let plain = w.activity(0.0, 16);
        let shifted = w.activity_with_shifts(0.0, 16, 2, 0.025);
        assert!((shifted.t_end() - plain.t_end() - 7.0 * 0.025).abs() < 1e-9);
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(workload_by_name("BERT").is_some());
        assert!(workload_by_name("nope").is_none());
    }
}
