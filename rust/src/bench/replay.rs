//! Workload trace replay: drive the simulator from recorded utilisation
//! traces instead of synthetic signatures.
//!
//! The paper's evaluation uses live benchmarks; production measurement
//! campaigns usually start from *recorded* telemetry (a DCGM/Prometheus
//! export). This module parses a simple `t_seconds,util` CSV into an
//! [`ActivitySignal`], plus a generator for realistic bursty production
//! traces (Poisson request arrivals with log-normal-ish service times) so
//! the fleet experiments can run on non-periodic load shapes.

use crate::measure::RepeatableLoad;
use crate::rng::Rng;
use crate::sim::activity::{ActivitySignal, Segment};

/// Parse a `t,util` CSV (header optional; comments with '#') into an
/// activity signal. Each row starts a segment lasting until the next row;
/// rows with util = 0 create gaps. Times must be non-decreasing.
///
/// Strictness (regression-pinned): every data row must have exactly two
/// columns — a row with trailing extra columns is rejected with its line
/// number rather than silently truncated — and CRLF (`\r\n`) line endings
/// are accepted. The only row allowed to be non-numeric is a single
/// two-column header as the first non-comment line.
pub fn parse_trace_csv(text: &str) -> Result<ActivitySignal, String> {
    let mut rows: Vec<(f64, f64)> = Vec::new();
    let mut seen_data_or_header = false;
    for (ln, line) in text.lines().enumerate() {
        // `str::lines` keeps a trailing '\r' on CRLF input; trim removes it
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split(',');
        let a = parts.next().map(str::trim).unwrap_or("");
        let b = parts.next().map(str::trim).unwrap_or("");
        let extra = parts.count();
        if extra > 0 {
            return Err(format!(
                "line {}: expected 2 columns (t_seconds,util), got {}",
                ln + 1,
                2 + extra
            ));
        }
        if !seen_data_or_header {
            seen_data_or_header = true;
            if a.parse::<f64>().is_err() && !b.is_empty() {
                continue; // two-column header row (first non-comment line)
            }
        }
        let t: f64 = a.parse().map_err(|_| format!("line {}: bad time '{a}'", ln + 1))?;
        let u: f64 = b.parse().map_err(|_| format!("line {}: bad util '{b}'", ln + 1))?;
        if !(0.0..=1.0).contains(&u) {
            return Err(format!("line {}: util {u} outside [0,1]", ln + 1));
        }
        if let Some(&(tp, _)) = rows.last() {
            if t < tp {
                return Err(format!("line {}: time goes backwards ({t} < {tp})", ln + 1));
            }
        }
        rows.push((t, u));
    }
    if rows.len() < 2 {
        return Err("trace needs at least 2 rows".into());
    }
    let mut act = ActivitySignal::idle();
    for w in rows.windows(2) {
        let (t0, u) = w[0];
        let (t1, _) = w[1];
        if u > 0.0 && t1 > t0 {
            act.push(t0, t1 - t0, u);
        }
    }
    Ok(act)
}

/// Render an activity signal back to the CSV format (round-trip support).
pub fn to_trace_csv(act: &ActivitySignal) -> String {
    let mut out = String::from("t_seconds,util\n");
    for seg in &act.segments {
        out.push_str(&format!("{:.6},{:.4}\n", seg.t0, seg.util));
        out.push_str(&format!("{:.6},0.0\n", seg.t1));
    }
    out
}

/// Generate a bursty "production inference service" trace: Poisson request
/// arrivals, each occupying the GPU for a sampled service time at a
/// sampled utilisation.
pub fn production_trace(
    t_start: f64,
    duration_s: f64,
    requests_per_s: f64,
    seed: u64,
) -> ActivitySignal {
    let mut rng = Rng::new(seed ^ 0x7EA7);
    let mut act = ActivitySignal::idle();
    let mut t = t_start;
    let mut busy_until = t_start;
    while t < t_start + duration_s {
        // exponential inter-arrival
        let gap = -rng.uniform().max(1e-12).ln() / requests_per_s;
        t += gap;
        if t >= t_start + duration_s {
            break;
        }
        // service time: heavy-ish tail, 5–80 ms
        let service = 0.005 + 0.02 * (-rng.uniform().max(1e-12).ln());
        let util = rng.uniform_range(0.5, 1.0);
        let begin = t.max(busy_until);
        if begin >= t_start + duration_s {
            break;
        }
        let end = (begin + service.min(0.08)).min(t_start + duration_s);
        act.push(begin, end - begin, util);
        busy_until = end;
    }
    act
}

/// A recorded trace as a repeatable measurement load: one "iteration" is
/// the whole recorded span, replayed back-to-back. This plugs recorded
/// production telemetry (DCGM/Prometheus exports parsed by
/// [`parse_trace_csv`], or [`production_trace`] shapes) straight into the
/// naive/good-practice procedures and the scheduler's streaming pipeline.
#[derive(Debug, Clone)]
pub struct ReplayLoad {
    /// Busy segments normalised so the recording starts at t = 0.
    base: Vec<Segment>,
    span_s: f64,
    name: String,
}

impl ReplayLoad {
    /// Wrap a recorded activity signal (must contain at least one busy
    /// segment; the recording's leading idle time is stripped).
    pub fn new(name: impl Into<String>, recorded: &ActivitySignal) -> Result<Self, String> {
        let Some(first) = recorded.segments.first() else {
            return Err("replay load needs at least one busy segment".into());
        };
        let t0 = first.t0;
        let span_s = recorded.t_end() - t0;
        if span_s <= 0.0 {
            return Err("replay load needs a positive recorded span".into());
        }
        let base = recorded
            .segments
            .iter()
            .map(|s| Segment { t0: s.t0 - t0, t1: s.t1 - t0, util: s.util })
            .collect();
        Ok(ReplayLoad { base, span_s, name: name.into() })
    }

    /// Parse a `t,util` CSV straight into a load.
    pub fn from_csv(name: impl Into<String>, text: &str) -> Result<Self, String> {
        ReplayLoad::new(name, &parse_trace_csv(text)?)
    }

    /// Duration of one replayed iteration (the recorded span), seconds.
    pub fn span_s(&self) -> f64 {
        self.span_s
    }
}

impl RepeatableLoad for ReplayLoad {
    fn iteration_s(&self) -> f64 {
        self.span_s
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64) -> ActivitySignal {
        let mut out = ActivitySignal::idle();
        self.build_into(t_start, reps, reps_per_shift, shift_s, &mut out);
        out
    }

    fn build_into(
        &self,
        t_start: f64,
        reps: usize,
        reps_per_shift: usize,
        shift_s: f64,
        out: &mut ActivitySignal,
    ) {
        out.segments.clear();
        let mut t = t_start;
        for k in 0..reps {
            for seg in &self.base {
                out.push(t + seg.t0, seg.t1 - seg.t0, seg.util);
            }
            t += self.span_s;
            if reps_per_shift > 0 && (k + 1) % reps_per_shift == 0 && k + 1 < reps {
                t += shift_s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_trace() {
        let csv = "t_seconds,util\n0.0,0.8\n1.0,0.0\n2.0,0.5\n3.0,0.0\n";
        let act = parse_trace_csv(csv).unwrap();
        assert_eq!(act.segments.len(), 2);
        assert_eq!(act.util_at(0.5), 0.8);
        assert_eq!(act.util_at(1.5), 0.0);
        assert_eq!(act.util_at(2.5), 0.5);
    }

    #[test]
    fn parse_rejects_bad_rows() {
        assert!(parse_trace_csv("0.0,1.5\n1.0,0.0").is_err()); // util > 1
        assert!(parse_trace_csv("1.0,0.5\n0.5,0.0").is_err()); // time backwards
        assert!(parse_trace_csv("0.0,0.5").is_err()); // too short
        assert!(parse_trace_csv("0.0,abc\n1.0,0.0").is_err());
    }

    #[test]
    fn parse_skips_comments_and_header() {
        let csv = "# recorded from dcgm\nt,util\n0.0,1.0\n0.5,0.0\n";
        let act = parse_trace_csv(csv).unwrap();
        assert_eq!(act.segments.len(), 1);
    }

    #[test]
    fn parse_rejects_extra_trailing_columns_with_line_number() {
        // regression: rows with extra columns used to be silently truncated
        let e = parse_trace_csv("0.0,0.5\n1.0,0.0,junk\n2.0,0.0").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("expected 2 columns"), "{e}");
        // a malformed header is rejected too, not skipped
        let e = parse_trace_csv("t,util,extra\n0.0,0.5\n1.0,0.0").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
    }

    #[test]
    fn parse_handles_crlf_line_endings() {
        let csv = "t_seconds,util\r\n0.0,0.8\r\n1.0,0.0\r\n2.0,0.5\r\n3.0,0.0\r\n";
        let act = parse_trace_csv(csv).unwrap();
        assert_eq!(act.segments.len(), 2);
        assert_eq!(act.util_at(0.5), 0.8);
    }

    #[test]
    fn parse_rejects_non_numeric_rows_after_the_header() {
        // only the first non-comment line may be a header
        let e = parse_trace_csv("0.0,0.5\nt,util\n1.0,0.0").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        // one-column garbage is an error, not a silently skipped header
        assert!(parse_trace_csv("garbage\n0.0,0.5\n1.0,0.0").is_err());
    }

    #[test]
    fn csv_roundtrip() {
        let act = ActivitySignal::square_wave(1.0, 0.2, 0.5, 0.7, 5);
        let back = parse_trace_csv(&to_trace_csv(&act)).unwrap();
        assert_eq!(back.segments.len(), act.segments.len());
        for (a, b) in act.segments.iter().zip(&back.segments) {
            assert!((a.t0 - b.t0).abs() < 1e-5 && (a.util - b.util).abs() < 1e-3);
        }
    }

    #[test]
    fn production_trace_is_plausible() {
        let act = production_trace(0.0, 10.0, 20.0, 1);
        // ~200 requests over 10 s, some coalesced
        assert!(act.segments.len() > 80, "{}", act.segments.len());
        let busy_frac = act.busy_time() / 10.0;
        assert!((0.1..0.9).contains(&busy_frac), "busy {busy_frac}");
        // segments are ordered and non-overlapping (push() enforces, but
        // double-check the generator's busy_until logic)
        for w in act.segments.windows(2) {
            assert!(w[1].t0 >= w[0].t1 - 1e-12);
        }
    }

    #[test]
    fn replay_load_repeats_recording() {
        let recorded = production_trace(2.0, 1.5, 30.0, 9);
        let load = ReplayLoad::new("prod", &recorded).unwrap();
        assert!((load.span_s() - (recorded.t_end() - recorded.t_start())).abs() < 1e-12);
        let act = load.build(0.5, 3, 0, 0.0);
        assert_eq!(act.segments.len(), 3 * recorded.segments.len());
        assert!((act.t_start() - 0.5).abs() < 1e-12);
        let with_shift = load.build(0.5, 4, 2, 0.1);
        assert!((with_shift.t_end() - (0.5 + 4.0 * load.span_s() + 0.1)).abs() < 1e-9);
        // build_into matches build exactly
        let mut reused = ActivitySignal::idle();
        load.build_into(0.5, 3, 0, 0.0, &mut reused);
        assert_eq!(reused.segments, act.segments);
    }

    #[test]
    fn replay_load_measures_with_both_pipelines() {
        use crate::measure::{
            measure_naive_streaming, naive::measure_naive, MeasureScratch, MeasurementRig,
        };
        use crate::sim::profile::{find_model, DriverEpoch, PowerField};
        let recorded = production_trace(0.0, 1.2, 40.0, 15);
        let load = ReplayLoad::new("prod", &recorded).unwrap();
        let device = crate::sim::GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, 77);
        let rig = MeasurementRig::new(device, DriverEpoch::Post530, PowerField::Instant, 78);
        let a = measure_naive(&rig, &load, 0.02, 4);
        assert!(a.energy_j > 0.0 && a.truth_j > 0.0, "{a:?}");
        let mut scratch = MeasureScratch::new();
        let b = measure_naive_streaming(&rig, &load, 0.02, 4, &mut scratch);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.truth_j.to_bits(), b.truth_j.to_bits());
    }

    #[test]
    fn replay_load_rejects_empty_recordings() {
        assert!(ReplayLoad::new("empty", &ActivitySignal::idle()).is_err());
        assert!(ReplayLoad::from_csv("bad", "0.0,0.5").is_err());
    }

    #[test]
    fn production_trace_measurable_end_to_end() {
        // replayed trace flows through the full stack
        use crate::sim::profile::{find_model, DriverEpoch, PowerField};
        let act = production_trace(0.5, 6.0, 30.0, 2);
        let device = crate::sim::GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, 3);
        let truth = device.synthesize(&act, 0.0, 7.0);
        let smi = crate::smi::NvidiaSmi::attach(device, DriverEpoch::Post530, &truth, 4);
        let log = smi.poll(PowerField::Instant, 0.02, 0.5, 6.5);
        assert!(log.series.points.len() > 200);
        let p = crate::measure::energy::mean_power(&log.series, 1.0, 6.0);
        assert!(p > 50.0 && p < 400.0, "p = {p}");
    }
}
