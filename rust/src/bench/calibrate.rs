//! Fig. 5: calibrate the FMA chain length → execution time relationship.
//!
//! The paper: "linear regression was used to determine the gradient between
//! the time measured for a set of arbitrary chain lengths" — both their
//! RTX 3090 and A100 fits have R² = 1.000. We do exactly that against the
//! real AOT kernel running on PJRT: time `fma_chain` for a sweep of `niter`
//! values and fit a line.

use anyhow::Result;

use crate::estimator::linreg::{fit, LinearFit};
use crate::runtime::ArtifactRuntime;

/// A niter → milliseconds calibration.
#[derive(Debug, Clone, Copy)]
pub struct Calibration {
    /// ms per iteration (the Fig. 5 slope).
    pub ms_per_iter: f64,
    /// fixed overhead ms (launch + readback).
    pub overhead_ms: f64,
    /// fit quality; the paper reports 1.000.
    pub r2: f64,
}

impl Calibration {
    /// Chain length needed for a target duration.
    pub fn niter_for_ms(&self, ms: f64) -> i32 {
        (((ms - self.overhead_ms) / self.ms_per_iter).round().max(1.0)) as i32
    }

    /// Predicted duration for a chain length.
    pub fn ms_for_niter(&self, niter: i32) -> f64 {
        self.overhead_ms + self.ms_per_iter * niter as f64
    }
}

/// Sweep + per-point timing data (for reporting the Fig. 5 scatter).
#[derive(Debug, Clone)]
pub struct CalibrationSweep {
    pub niters: Vec<i32>,
    pub measured_ms: Vec<f64>,
    pub fit: LinearFit,
}

/// Time the kernel for `niters` (each `reps` times, keeping the minimum —
/// standard microbenchmark practice) and fit the line.
pub fn calibrate_sweep(rt: &ArtifactRuntime, niters: &[i32], reps: usize) -> Result<CalibrationSweep> {
    let x = vec![0.5f32; rt.manifest.nsize];
    // warm-up: first execution pays one-time costs
    let _ = rt.fma_chain(niters[0], &x)?;
    let mut measured = Vec::with_capacity(niters.len());
    for &n in niters {
        let mut best = f64::INFINITY;
        for _ in 0..reps.max(1) {
            let (_, dt) = rt.fma_chain(n, &x)?;
            best = best.min(dt.as_secs_f64() * 1000.0);
        }
        measured.push(best);
    }
    let xs: Vec<f64> = niters.iter().map(|&n| n as f64).collect();
    let f = fit(&xs, &measured);
    Ok(CalibrationSweep { niters: niters.to_vec(), measured_ms: measured, fit: f })
}

/// Standard calibration: geometric sweep of chain lengths. The sweep spans
/// the range the benchmark loads actually use (tens of ms), so the fit
/// interpolates rather than extrapolates.
pub fn calibrate(rt: &ArtifactRuntime) -> Result<Calibration> {
    let niters = [1000, 2000, 4000, 8000, 16000, 32000, 64000];
    let sweep = calibrate_sweep(rt, &niters, 3)?;
    Ok(Calibration {
        ms_per_iter: sweep.fit.slope,
        overhead_ms: sweep.fit.intercept.max(0.0),
        r2: sweep.fit.r2,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn niter_roundtrip() {
        let c = Calibration { ms_per_iter: 0.01, overhead_ms: 0.5, r2: 1.0 };
        let n = c.niter_for_ms(50.0);
        assert_eq!(n, 4950);
        assert!((c.ms_for_niter(n) - 50.0).abs() < 0.01);
    }

    #[test]
    fn niter_never_below_one() {
        let c = Calibration { ms_per_iter: 1.0, overhead_ms: 10.0, r2: 1.0 };
        assert_eq!(c.niter_for_ms(0.1), 1);
    }
}
