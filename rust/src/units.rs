//! Canonical unit conversions for the power/energy pipeline.
//!
//! Every foreign telemetry schema arrives in its own units — NVML reports
//! **milliwatts**, amdsmi integer **watts**, DCGM float watts against
//! **millisecond** epoch timestamps, IPMI integer watts per host rail —
//! and the accounting layer reports **joules** rolled up to kilojoules
//! and annualised kWh. Before this module each conversion was an ad-hoc
//! `/ 1000.0` at its call site, which is exactly how a milliwatt adapter
//! multiplies a latent factor-of-1000 bug. All scale changes now route
//! through these helpers.
//!
//! Bit-compatibility note: the helpers deliberately keep the *same
//! floating-point operation order* as the expressions they replaced
//! (`x / 1000.0`, `w * 24.0 * 365.0 / 1000.0`, …), so swapping a call
//! site over is bit-for-bit neutral — pinned by tests below.

/// Milliseconds per second.
pub const MS_PER_S: f64 = 1000.0;
/// Milliwatts per watt (NVML's `nvmlDeviceGetPowerUsage` unit).
pub const MW_PER_W: f64 = 1000.0;
/// Joules per kilojoule.
pub const J_PER_KJ: f64 = 1000.0;
/// Joules per kilowatt-hour.
pub const J_PER_KWH: f64 = 3.6e6;
/// Hours in the accounting year used by the paper's cost projection.
pub const HOURS_PER_YEAR: f64 = 24.0 * 365.0;

/// Milliwatts → watts (NVML power readings).
#[inline]
pub fn mw_to_w(mw: f64) -> f64 {
    mw / MW_PER_W
}

/// Watts → milliwatts (NVML log writer).
#[inline]
pub fn w_to_mw(w: f64) -> f64 {
    w * MW_PER_W
}

/// Milliseconds → seconds (DCGM/Prometheus timestamps, identified
/// sensor windows).
#[inline]
pub fn ms_to_s(ms: f64) -> f64 {
    ms / MS_PER_S
}

/// Seconds → milliseconds.
#[inline]
pub fn s_to_ms(s: f64) -> f64 {
    s * MS_PER_S
}

/// Joules → kilojoules (table rendering).
#[inline]
pub fn j_to_kj(j: f64) -> f64 {
    j / J_PER_KJ
}

/// Joules → kilowatt-hours (cost accounting).
#[inline]
pub fn j_to_kwh(j: f64) -> f64 {
    j / J_PER_KWH
}

/// A steady draw of `w` watts → kWh consumed per year. Same operation
/// order as the annual-cost expressions this replaced
/// (`w * 24.0 * 365.0 / 1000.0`), so the USD projections are unchanged
/// bit-for-bit.
#[inline]
pub fn w_to_kwh_per_year(w: f64) -> f64 {
    w * 24.0 * 365.0 / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_constants_are_exact() {
        // all power-of-ten scales used here are exactly representable
        assert_eq!(MS_PER_S, 1000.0);
        assert_eq!(MS_PER_S, 1e3);
        assert_eq!(MW_PER_W, 1000.0);
        assert_eq!(J_PER_KJ, 1e3);
        assert_eq!(J_PER_KWH, 3_600_000.0);
        assert_eq!(HOURS_PER_YEAR, 8760.0);
    }

    #[test]
    fn milliwatt_round_trips() {
        assert_eq!(mw_to_w(61_150.0), 61.15);
        assert_eq!(mw_to_w(0.0), 0.0);
        assert_eq!(w_to_mw(250.0), 250_000.0);
        // exact for every integer milliwatt value a sensor can report
        for mw in [1u64, 999, 1_000, 65_535, 300_000, 700_001] {
            let w = mw_to_w(mw as f64);
            assert_eq!(w_to_mw(w).round() as u64, mw, "{mw} mW");
        }
    }

    #[test]
    fn time_round_trips() {
        assert_eq!(ms_to_s(1500.0), 1.5);
        assert_eq!(s_to_ms(0.1), 100.0);
        for ms in [0u64, 1, 100, 999, 1_000, 86_400_000] {
            assert_eq!(s_to_ms(ms_to_s(ms as f64)).round() as u64, ms, "{ms} ms");
        }
    }

    #[test]
    fn energy_conversions() {
        assert_eq!(j_to_kj(2500.0), 2.5);
        assert_eq!(j_to_kwh(3.6e6), 1.0);
        assert_eq!(j_to_kwh(1.8e6), 0.5);
        // a 1 kW draw burns 8760 kWh in the accounting year
        assert_eq!(w_to_kwh_per_year(1000.0), 8760.0);
    }

    /// The helpers replaced in-line expressions; these pins guarantee the
    /// swap is bit-for-bit neutral at the original call sites.
    #[test]
    fn bit_identical_to_replaced_expressions() {
        for x in [0.0, 1.0e-12, 0.37, 61.15, 1234.567, 9.9e9] {
            assert_eq!(j_to_kj(x).to_bits(), (x / 1e3).to_bits());
            assert_eq!(mw_to_w(x).to_bits(), (x / 1000.0).to_bits());
            assert_eq!(ms_to_s(x).to_bits(), (x / 1000.0).to_bits());
            assert_eq!(s_to_ms(x).to_bits(), (x * 1000.0).to_bits());
            assert_eq!(
                w_to_kwh_per_year(x).to_bits(),
                (x * 24.0 * 365.0 / 1000.0).to_bits()
            );
        }
    }

    #[test]
    fn conversions_are_monotone_and_total() {
        // NaN propagates, infinities stay infinite, no panics anywhere
        assert!(mw_to_w(f64::NAN).is_nan());
        assert_eq!(j_to_kwh(f64::INFINITY), f64::INFINITY);
        assert!(ms_to_s(-5.0) < 0.0);
        let mut prev = f64::NEG_INFINITY;
        for x in [-1.0e6, -1.0, 0.0, 1.0, 1.0e6] {
            let y = w_to_kwh_per_year(x);
            assert!(y >= prev);
            prev = y;
        }
    }
}
