//! Measurement scheduler: run measurement jobs across the fleet
//! concurrently (std scoped threads — this environment is offline, so the
//! coordinator uses a dependency-free worker pool) and aggregate results.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use super::fleet::{Fleet, FleetReport};
use crate::bench::workloads::{Workload, WORKLOADS};
use crate::measure::{
    good_practice::measure_good_practice, naive::measure_naive, GoodPracticeConfig,
    MeasurementRig, SensorCharacterization,
};
use crate::sim::profile::sensor_pipeline;
use crate::sim::PipelineKind;

/// One measurement job: a workload on one node.
#[derive(Debug, Clone)]
pub struct MeasurementJob {
    pub node_id: usize,
    pub workload: &'static Workload,
}

/// Outcome of one job.
#[derive(Debug, Clone)]
pub struct MeasurementOutcome {
    pub node_id: usize,
    pub workload: &'static str,
    pub model: &'static str,
    pub naive_pct_error: f64,
    pub good_pct_error: f64,
    /// Good-practice measured power, watts.
    pub power_w: f64,
    /// One-iteration ground-truth energy, joules.
    pub truth_j: f64,
}

/// Fleet-wide measurement scheduler: a fixed pool of workers pulling node
/// jobs from a shared queue.
#[derive(Debug)]
pub struct Scheduler {
    /// Max concurrent node measurements.
    pub concurrency: usize,
    pub config: GoodPracticeConfig,
}

impl Default for Scheduler {
    fn default() -> Self {
        Scheduler { concurrency: num_threads(), config: GoodPracticeConfig::default() }
    }
}

fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Measure one node; `None` when the sensor is unsupported (Fermi).
fn measure_node(
    device: crate::sim::GpuDevice,
    node_id: usize,
    driver: crate::sim::DriverEpoch,
    field: crate::sim::PowerField,
    wl: &'static Workload,
    cfg: &GoodPracticeConfig,
) -> Option<MeasurementOutcome> {
    let spec = sensor_pipeline(device.model.generation, field, driver);
    if !spec.is_measured() {
        return None;
    }
    let sensor = SensorCharacterization {
        update_s: spec.update_ms / 1000.0,
        window_s: match spec.kind {
            PipelineKind::Boxcar { window_ms } => window_ms / 1000.0,
            _ => spec.update_ms / 1000.0,
        },
        rise_s: device.model.rise_ms / 1000.0,
    };
    let model = device.model.name;
    let rig = MeasurementRig::new(device, driver, field, 0xF1EE7 ^ node_id as u64);
    let naive = measure_naive(&rig, wl, cfg.poll_period_s, node_id as u64);
    let good = measure_good_practice(&rig, wl, &sensor, cfg);
    Some(MeasurementOutcome {
        node_id,
        workload: wl.name,
        model,
        naive_pct_error: naive.pct_error,
        good_pct_error: good.mean_pct_error,
        power_w: good.mean_power_w,
        truth_j: naive.truth_j,
    })
}

impl Scheduler {
    /// Run one workload on every fleet node (round-robin through the
    /// Table 2 suite when `workload` is `None`), measuring each node with
    /// both the naive and the good-practice method.
    pub fn run(
        &self,
        fleet: &Fleet,
        workload: Option<&'static Workload>,
    ) -> (Vec<MeasurementOutcome>, FleetReport) {
        let jobs: Vec<MeasurementJob> = fleet
            .nodes
            .iter()
            .map(|n| MeasurementJob {
                node_id: n.id,
                workload: workload.unwrap_or(&WORKLOADS[n.id % WORKLOADS.len()]),
            })
            .collect();
        let queue = Arc::new(Mutex::new(jobs));
        let (tx, rx) = mpsc::channel::<MeasurementOutcome>();
        let driver = fleet.config.driver;
        let field = fleet.config.field;
        let cfg = self.config;

        std::thread::scope(|scope| {
            for _ in 0..self.concurrency.max(1) {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let nodes = &fleet.nodes;
                scope.spawn(move || loop {
                    let job = { queue.lock().unwrap().pop() };
                    let Some(job) = job else { break };
                    let device = nodes[job.node_id].device.clone();
                    if let Some(out) =
                        measure_node(device, job.node_id, driver, field, job.workload, &cfg)
                    {
                        let _ = tx.send(out);
                    }
                });
            }
            drop(tx);
        });

        let mut outcomes: Vec<MeasurementOutcome> = rx.into_iter().collect();
        outcomes.sort_by_key(|o| o.node_id);

        let mut report = FleetReport::default();
        for o in &outcomes {
            report.truth_j += o.truth_j;
            report.naive_j += o.truth_j * (1.0 + o.naive_pct_error / 100.0);
            report.good_j += o.truth_j * (1.0 + o.good_pct_error / 100.0);
            report.node_errors.push((o.naive_pct_error, o.good_pct_error));
        }
        report.nodes_measured = outcomes.len();
        (outcomes, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::FleetConfig;
    use crate::sim::profile::{DriverEpoch, PowerField};

    fn small_cfg() -> GoodPracticeConfig {
        // keep tests fast: fewer trials, shorter runtime floor
        GoodPracticeConfig { trials: 2, min_reps: 8, min_runtime_s: 1.0, ..Default::default() }
    }

    #[test]
    fn scheduler_measures_all_nodes() {
        let fleet = Fleet::build(FleetConfig {
            size: 4,
            models: vec!["A100".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed: 5,
        });
        let sched = Scheduler { concurrency: 2, config: small_cfg() };
        let (outcomes, report) = sched.run(&fleet, None);
        assert_eq!(outcomes.len(), 4);
        assert_eq!(report.nodes_measured, 4);
        assert!(report.truth_j > 0.0);
    }

    #[test]
    fn good_practice_beats_naive_fleetwide() {
        let fleet = Fleet::build(FleetConfig {
            size: 6,
            models: vec!["A100".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed: 11,
        });
        let sched = Scheduler { concurrency: 4, config: small_cfg() };
        let (outcomes, _) = sched.run(&fleet, Some(&WORKLOADS[0]));
        let mean_abs = |f: &dyn Fn(&MeasurementOutcome) -> f64| {
            outcomes.iter().map(|o| f(o).abs()).sum::<f64>() / outcomes.len() as f64
        };
        let naive = mean_abs(&|o| o.naive_pct_error);
        let good = mean_abs(&|o| o.good_pct_error);
        assert!(good < naive, "good practice ({good:.1}%) must beat naive ({naive:.1}%)");
    }

    #[test]
    fn unmeasurable_nodes_are_skipped() {
        let fleet = Fleet::build(FleetConfig {
            size: 3,
            models: vec!["C2050".into()],
            driver: DriverEpoch::Pre530,
            field: PowerField::Draw,
            seed: 2,
        });
        let sched = Scheduler { concurrency: 2, config: small_cfg() };
        let (outcomes, report) = sched.run(&fleet, None);
        assert!(outcomes.is_empty());
        assert_eq!(report.nodes_measured, 0);
    }

    #[test]
    fn deterministic_across_concurrency_levels() {
        let fleet = Fleet::build(FleetConfig {
            size: 5,
            models: vec!["3090".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed: 21,
        });
        let a = Scheduler { concurrency: 1, config: small_cfg() }.run(&fleet, None).0;
        let b = Scheduler { concurrency: 4, config: small_cfg() }.run(&fleet, None).0;
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.node_id, y.node_id);
            assert!((x.good_pct_error - y.good_pct_error).abs() < 1e-12);
        }
    }
}
