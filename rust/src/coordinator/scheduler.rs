//! Measurement scheduler: run measurement jobs across the fleet
//! concurrently (std scoped threads — this environment is offline, so the
//! coordinator uses a dependency-free worker pool) and aggregate results.
//!
//! Two execution modes:
//! * [`Scheduler::run`] — the materialised reference path: one
//!   `PowerTrace` + `NvidiaSmi` per capture, jobs pulled from a shared
//!   queue. Kept as the baseline the campaign mode is benchmarked (and
//!   bit-for-bit verified) against.
//! * [`Scheduler::run_campaign`] — the fleet-scale streaming path: jobs
//!   are processed in **shards** (contiguous node ranges with
//!   deterministic per-shard seeds, no per-node queue entries), and every
//!   worker drives the chunked capture through one reused
//!   [`MeasureScratch`] arena, so a 1k–10k-node campaign does O(chunk)
//!   allocation per node instead of O(trace).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use super::fleet::{Fleet, FleetReport};
use crate::bench::workloads::{Workload, WORKLOADS};
use crate::measure::good_practice::good_practice_core;
use crate::measure::{
    good_practice::measure_good_practice, naive::measure_naive, naive::measure_naive_streaming,
    GoodPracticeConfig, MeasureScratch, MeasurementRig, SensorCharacterization,
};
use crate::rng::splitmix64;
use crate::sim::profile::sensor_pipeline;
use crate::sim::PipelineKind;

/// One measurement job: a workload on one node.
#[derive(Debug, Clone)]
pub struct MeasurementJob {
    pub node_id: usize,
    pub workload: &'static Workload,
}

/// Outcome of one job.
#[derive(Debug, Clone)]
pub struct MeasurementOutcome {
    pub node_id: usize,
    pub workload: &'static str,
    pub model: &'static str,
    pub naive_pct_error: f64,
    pub good_pct_error: f64,
    /// Good-practice measured power, watts.
    pub power_w: f64,
    /// One-iteration ground-truth energy, joules.
    pub truth_j: f64,
    /// Duration of the naive measurement window, seconds (feeds the fleet
    /// report's mean-draw derivation).
    pub window_s: f64,
}

/// Sharding parameters for [`Scheduler::run_campaign`].
#[derive(Debug, Clone, Copy)]
pub struct CampaignConfig {
    /// Nodes per shard (contiguous node-id ranges; workers claim whole
    /// shards, so queue traffic is O(nodes / shard_size)).
    pub shard_size: usize,
    /// Campaign seed. `0` (the default) reproduces [`Scheduler::run`]
    /// bit-for-bit; any other value mixes a deterministic per-shard seed
    /// into every node's *rig* seed, re-randomising the whole per-node
    /// measurement setup — sensor boot phases, trial alignment delays,
    /// and the PMD instrument pairing — while staying reproducible for a
    /// fixed `(seed, shard_size)`. Use it to model independent repeats of
    /// a campaign, not a pure re-boot (a re-boot alone would keep the
    /// same physical PMD).
    pub seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig { shard_size: 64, seed: 0 }
    }
}

/// Deterministic per-shard seed (independent of worker count and claim
/// order).
pub fn shard_seed(campaign_seed: u64, shard_index: usize) -> u64 {
    let mut s = campaign_seed ^ (shard_index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// Per-node rig seed; `extra` is 0 in reference mode (and for campaign
/// seed 0), keeping both schedulers on identical boot phases.
fn node_rig_seed(node_id: usize, extra: u64) -> u64 {
    0xF1EE7 ^ node_id as u64 ^ extra
}

/// The sensor characterisation the campaign hands the good practice —
/// shared by both scheduler modes.
fn node_sensor(
    device: &crate::sim::GpuDevice,
    field: crate::sim::PowerField,
    driver: crate::sim::DriverEpoch,
) -> Option<SensorCharacterization> {
    let spec = sensor_pipeline(device.model.generation, field, driver);
    if !spec.is_measured() {
        return None;
    }
    Some(SensorCharacterization {
        update_s: crate::units::ms_to_s(spec.update_ms),
        window_s: match spec.kind {
            PipelineKind::Boxcar { window_ms } => crate::units::ms_to_s(window_ms),
            _ => crate::units::ms_to_s(spec.update_ms),
        },
        rise_s: crate::units::ms_to_s(device.model.rise_ms),
    })
}

/// Measure one node; `None` when the sensor is unsupported (Fermi).
fn measure_node(
    device: crate::sim::GpuDevice,
    node_id: usize,
    driver: crate::sim::DriverEpoch,
    field: crate::sim::PowerField,
    wl: &'static Workload,
    cfg: &GoodPracticeConfig,
) -> Option<MeasurementOutcome> {
    let sensor = node_sensor(&device, field, driver)?;
    let model = device.model.name;
    let rig = MeasurementRig::new(device, driver, field, node_rig_seed(node_id, 0));
    let naive = measure_naive(&rig, wl, cfg.poll_period_s, node_id as u64);
    let good = measure_good_practice(&rig, wl, &sensor, cfg);
    Some(MeasurementOutcome {
        node_id,
        workload: wl.name,
        model,
        naive_pct_error: naive.pct_error,
        good_pct_error: good.mean_pct_error,
        power_w: good.mean_power_w,
        truth_j: naive.truth_j,
        window_s: naive.window_s,
    })
}

/// [`measure_node`] on the streaming pipeline with a reused per-worker
/// scratch arena; identical outcomes for `seed_extra == 0` (pinned by
/// tests and the hotpath campaign benchmark).
fn measure_node_streaming(
    device: crate::sim::GpuDevice,
    node_id: usize,
    driver: crate::sim::DriverEpoch,
    field: crate::sim::PowerField,
    wl: &'static Workload,
    cfg: &GoodPracticeConfig,
    seed_extra: u64,
    scratch: &mut MeasureScratch,
) -> Option<MeasurementOutcome> {
    let sensor = node_sensor(&device, field, driver)?;
    let model = device.model.name;
    let rig = MeasurementRig::new(device, driver, field, node_rig_seed(node_id, seed_extra));
    let naive = measure_naive_streaming(&rig, wl, cfg.poll_period_s, node_id as u64, scratch);
    let good = good_practice_core(&rig, wl, &sensor, cfg, scratch);
    Some(MeasurementOutcome {
        node_id,
        workload: wl.name,
        model,
        naive_pct_error: naive.pct_error,
        good_pct_error: good.mean_pct_error,
        power_w: good.mean_power_w,
        truth_j: naive.truth_j,
        window_s: naive.window_s,
    })
}

/// Fleet-wide measurement scheduler: a fixed pool of workers pulling node
/// jobs from a shared queue.
#[derive(Debug)]
pub struct Scheduler {
    /// Max concurrent node measurements.
    pub concurrency: usize,
    pub config: GoodPracticeConfig,
}

impl Default for Scheduler {
    fn default() -> Self {
        Scheduler { concurrency: num_threads(), config: GoodPracticeConfig::default() }
    }
}

fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl Scheduler {
    /// Run one workload on every fleet node (round-robin through the
    /// Table 2 suite when `workload` is `None`), measuring each node with
    /// both the naive and the good-practice method. This is the
    /// materialised reference path.
    pub fn run(
        &self,
        fleet: &Fleet,
        workload: Option<&'static Workload>,
    ) -> (Vec<MeasurementOutcome>, FleetReport) {
        let jobs: Vec<MeasurementJob> = fleet
            .nodes
            .iter()
            .map(|n| MeasurementJob {
                node_id: n.id,
                workload: workload.unwrap_or(&WORKLOADS[n.id % WORKLOADS.len()]),
            })
            .collect();
        let queue = Arc::new(Mutex::new(jobs));
        let (tx, rx) = mpsc::channel::<MeasurementOutcome>();
        let driver = fleet.config.driver;
        let field = fleet.config.field;
        let cfg = self.config;

        std::thread::scope(|scope| {
            for _ in 0..self.concurrency.max(1) {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                let nodes = &fleet.nodes;
                scope.spawn(move || loop {
                    let job = { queue.lock().unwrap().pop() };
                    let Some(job) = job else { break };
                    let device = nodes[job.node_id].device.clone();
                    if let Some(out) =
                        measure_node(device, job.node_id, driver, field, job.workload, &cfg)
                    {
                        let _ = tx.send(out);
                    }
                });
            }
            drop(tx);
        });

        let mut outcomes: Vec<MeasurementOutcome> = rx.into_iter().collect();
        outcomes.sort_by_key(|o| o.node_id);
        let report = FleetReport::from_outcomes(&outcomes);
        (outcomes, report)
    }

    /// Fleet-scale streaming campaign: workers claim shards (contiguous
    /// node ranges) off an atomic counter and measure each node through
    /// the chunked, allocation-free pipeline with one scratch arena per
    /// worker. With `campaign.seed == 0` the outcomes are bit-for-bit
    /// identical to [`Self::run`]; results are deterministic for a fixed
    /// `(seed, shard_size)` regardless of concurrency.
    pub fn run_campaign(
        &self,
        fleet: &Fleet,
        workload: Option<&'static Workload>,
        campaign: CampaignConfig,
    ) -> (Vec<MeasurementOutcome>, FleetReport) {
        let n = fleet.nodes.len();
        let shard_size = campaign.shard_size.max(1);
        let n_shards = (n + shard_size - 1) / shard_size;
        let next_shard = AtomicUsize::new(0);
        let driver = fleet.config.driver;
        let field = fleet.config.field;
        let cfg = self.config;
        let workers = self.concurrency.max(1);

        let mut outcomes: Vec<MeasurementOutcome> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let next_shard = &next_shard;
                    let nodes = &fleet.nodes;
                    scope.spawn(move || {
                        let mut scratch = MeasureScratch::new();
                        let mut local: Vec<MeasurementOutcome> = Vec::new();
                        loop {
                            let s = next_shard.fetch_add(1, Ordering::Relaxed);
                            if s >= n_shards {
                                break;
                            }
                            let seed_extra =
                                if campaign.seed == 0 { 0 } else { shard_seed(campaign.seed, s) };
                            let lo = s * shard_size;
                            let hi = (lo + shard_size).min(n);
                            for node in &nodes[lo..hi] {
                                let wl = workload
                                    .unwrap_or(&WORKLOADS[node.id % WORKLOADS.len()]);
                                if let Some(out) = measure_node_streaming(
                                    node.device.clone(),
                                    node.id,
                                    driver,
                                    field,
                                    wl,
                                    &cfg,
                                    seed_extra,
                                    &mut scratch,
                                ) {
                                    local.push(out);
                                }
                            }
                        }
                        local
                    })
                })
                .collect();
            let mut all = Vec::with_capacity(n);
            for h in handles {
                all.extend(h.join().expect("campaign worker panicked"));
            }
            all
        });
        outcomes.sort_by_key(|o| o.node_id);
        let report = FleetReport::from_outcomes(&outcomes);
        (outcomes, report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fleet::FleetConfig;
    use crate::sim::profile::{DriverEpoch, PowerField};

    fn small_cfg() -> GoodPracticeConfig {
        // keep tests fast: fewer trials, shorter runtime floor
        GoodPracticeConfig { trials: 2, min_reps: 8, min_runtime_s: 1.0, ..Default::default() }
    }

    fn small_fleet(size: usize, models: &[&str], seed: u64) -> Fleet {
        Fleet::build(FleetConfig {
            size,
            models: models.iter().map(|m| m.to_string()).collect(),
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed,
        })
    }

    fn assert_outcomes_identical(a: &[MeasurementOutcome], b: &[MeasurementOutcome]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            let id = x.node_id;
            assert_eq!(x.node_id, y.node_id);
            assert_eq!(x.workload, y.workload);
            assert_eq!(x.model, y.model);
            assert_eq!(x.naive_pct_error.to_bits(), y.naive_pct_error.to_bits(), "node {id}");
            assert_eq!(x.good_pct_error.to_bits(), y.good_pct_error.to_bits(), "node {id}");
            assert_eq!(x.power_w.to_bits(), y.power_w.to_bits(), "node {id}");
            assert_eq!(x.truth_j.to_bits(), y.truth_j.to_bits(), "node {id}");
            assert_eq!(x.window_s.to_bits(), y.window_s.to_bits(), "node {id}");
        }
    }

    #[test]
    fn scheduler_measures_all_nodes() {
        let fleet = small_fleet(4, &["A100"], 5);
        let sched = Scheduler { concurrency: 2, config: small_cfg() };
        let (outcomes, report) = sched.run(&fleet, None);
        assert_eq!(outcomes.len(), 4);
        assert_eq!(report.nodes_measured, 4);
        assert!(report.truth_j > 0.0);
        assert!(report.measured_s > 0.0);
    }

    #[test]
    fn good_practice_beats_naive_fleetwide() {
        let fleet = small_fleet(6, &["A100"], 11);
        let sched = Scheduler { concurrency: 4, config: small_cfg() };
        let (outcomes, _) = sched.run(&fleet, Some(&WORKLOADS[0]));
        let mean_abs = |f: &dyn Fn(&MeasurementOutcome) -> f64| {
            outcomes.iter().map(|o| f(o).abs()).sum::<f64>() / outcomes.len() as f64
        };
        let naive = mean_abs(&|o| o.naive_pct_error);
        let good = mean_abs(&|o| o.good_pct_error);
        assert!(good < naive, "good practice ({good:.1}%) must beat naive ({naive:.1}%)");
    }

    #[test]
    fn unmeasurable_nodes_are_skipped() {
        let fleet = Fleet::build(FleetConfig {
            size: 3,
            models: vec!["C2050".into()],
            driver: DriverEpoch::Pre530,
            field: PowerField::Draw,
            seed: 2,
        });
        let sched = Scheduler { concurrency: 2, config: small_cfg() };
        let (outcomes, report) = sched.run(&fleet, None);
        assert!(outcomes.is_empty());
        assert_eq!(report.nodes_measured, 0);
        // campaign mode must agree
        let (c, _) = sched.run_campaign(&fleet, None, CampaignConfig::default());
        assert!(c.is_empty());
    }

    #[test]
    fn deterministic_across_concurrency_levels() {
        let fleet = small_fleet(5, &["3090"], 21);
        let a = Scheduler { concurrency: 1, config: small_cfg() }.run(&fleet, None).0;
        let b = Scheduler { concurrency: 4, config: small_cfg() }.run(&fleet, None).0;
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.node_id, y.node_id);
            assert!((x.good_pct_error - y.good_pct_error).abs() < 1e-12);
        }
    }

    #[test]
    fn campaign_matches_reference_scheduler_bit_for_bit() {
        // the acceptance criterion: streaming campaign == materialised run
        let fleet = small_fleet(5, &["A100", "3090"], 31);
        let sched = Scheduler { concurrency: 2, config: small_cfg() };
        let (a, ra) = sched.run(&fleet, None);
        let (b, rb) = sched.run_campaign(&fleet, None, CampaignConfig::default());
        assert_outcomes_identical(&a, &b);
        assert_eq!(ra.truth_j.to_bits(), rb.truth_j.to_bits());
        assert_eq!(ra.measured_s.to_bits(), rb.measured_s.to_bits());
    }

    #[test]
    fn campaign_invariant_to_shard_size_and_concurrency_at_seed_zero() {
        let fleet = small_fleet(7, &["A100"], 41);
        let sched1 = Scheduler { concurrency: 1, config: small_cfg() };
        let sched4 = Scheduler { concurrency: 4, config: small_cfg() };
        let shard = |s| CampaignConfig { shard_size: s, seed: 0 };
        let (a, _) = sched1.run_campaign(&fleet, Some(&WORKLOADS[2]), shard(1));
        let (b, _) = sched4.run_campaign(&fleet, Some(&WORKLOADS[2]), shard(3));
        let (c, _) = sched4.run_campaign(&fleet, Some(&WORKLOADS[2]), shard(64));
        assert_outcomes_identical(&a, &b);
        assert_outcomes_identical(&a, &c);
    }

    #[test]
    fn campaign_reseed_changes_boot_phases_deterministically() {
        let fleet = small_fleet(4, &["A100"], 51);
        let sched = Scheduler { concurrency: 2, config: small_cfg() };
        let base = CampaignConfig { shard_size: 2, seed: 0 };
        let reseeded = CampaignConfig { shard_size: 2, seed: 777 };
        let (a, _) = sched.run_campaign(&fleet, Some(&WORKLOADS[0]), base);
        let (b, _) = sched.run_campaign(&fleet, Some(&WORKLOADS[0]), reseeded);
        let (b2, _) = sched.run_campaign(&fleet, Some(&WORKLOADS[0]), reseeded);
        // same nodes measured, different boot phases, reproducible reseed
        assert_eq!(a.len(), b.len());
        assert!(
            a.iter().zip(&b).any(|(x, y)| x.naive_pct_error != y.naive_pct_error),
            "reseeding must perturb at least one node's phases"
        );
        assert_outcomes_identical(&b, &b2);
    }
}
