//! Fleet coordinator: datacenter-scale measurement campaigns over many
//! simulated GPUs, on a dependency-free std scoped-thread worker pool
//! (this environment is offline — no async runtime is involved).
//!
//! The paper's motivation is fleet-level: "for a data centre with 10,000
//! GPUs [a ±5% error] would lead to an extra $1 million in electricity cost
//! yearly". The coordinator instantiates a mixed fleet from the catalogue,
//! runs workloads on every card concurrently, measures each with both the
//! naive method and the good practice, and aggregates the fleet-level
//! energy accounting error. The streaming campaign mode
//! ([`Scheduler::run_campaign`]) shards the fleet into contiguous node
//! ranges with deterministic per-shard seeds and reuses one scratch arena
//! per worker, so campaigns scale past the one-Vec-per-node design.

pub mod fleet;
pub mod scheduler;

pub use fleet::{Fleet, FleetConfig, FleetReport};
pub use scheduler::{shard_seed, CampaignConfig, MeasurementJob, MeasurementOutcome, Scheduler};
