//! Fleet coordinator: datacenter-scale measurement campaigns over many
//! simulated GPUs (tokio).
//!
//! The paper's motivation is fleet-level: "for a data centre with 10,000
//! GPUs [a ±5% error] would lead to an extra $1 million in electricity cost
//! yearly". The coordinator instantiates a mixed fleet from the catalogue,
//! runs workloads on every card concurrently, measures each with both the
//! naive method and the good practice, and aggregates the fleet-level
//! energy accounting error.

pub mod fleet;
pub mod scheduler;

pub use fleet::{Fleet, FleetConfig, FleetReport};
pub use scheduler::{MeasurementJob, MeasurementOutcome, Scheduler};
