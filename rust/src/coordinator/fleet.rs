//! A simulated datacenter fleet of GPUs.

use crate::rng::Rng;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{DriverEpoch, GpuModel, PowerField, CATALOGUE};

/// Fleet composition config.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of cards.
    pub size: usize,
    /// Restrict to these model-name substrings (empty = whole catalogue,
    /// weighted by the paper's tested counts).
    pub models: Vec<String>,
    /// Driver epoch for every node.
    pub driver: DriverEpoch,
    /// Power field queried by the telemetry collector.
    pub field: PowerField,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            size: 64,
            models: Vec::new(),
            driver: DriverEpoch::Post530,
            field: PowerField::Draw,
            seed: 7,
        }
    }
}

/// One fleet node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub device: GpuDevice,
}

/// The instantiated fleet.
#[derive(Debug)]
pub struct Fleet {
    pub nodes: Vec<Node>,
    pub config: FleetConfig,
}

impl Fleet {
    /// Build a fleet: models drawn from the catalogue proportionally to the
    /// paper's tested counts (or the filtered subset).
    pub fn build(config: FleetConfig) -> Self {
        let pool: Vec<&'static GpuModel> = if config.models.is_empty() {
            CATALOGUE.iter().collect()
        } else {
            CATALOGUE
                .iter()
                .filter(|m| {
                    config
                        .models
                        .iter()
                        .any(|q| m.name.to_lowercase().contains(&q.to_lowercase()))
                })
                .collect()
        };
        assert!(!pool.is_empty(), "no models matched the fleet filter");
        // weighted by tested_count
        let weights: Vec<u32> = pool.iter().map(|m| m.tested_count.max(1)).collect();
        let total: u32 = weights.iter().sum();
        let mut rng = Rng::new(config.seed);
        let nodes = (0..config.size)
            .map(|id| {
                let mut pick = rng.below(total as u64) as u32;
                let mut model = pool[0];
                for (m, w) in pool.iter().zip(&weights) {
                    if pick < *w {
                        model = m;
                        break;
                    }
                    pick -= w;
                }
                Node { id, device: GpuDevice::new(model, id as u32, config.seed) }
            })
            .collect();
        Fleet { nodes, config }
    }

    /// Fleet size.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Aggregated fleet measurement report.
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    /// Sum of ground-truth energies, joules.
    pub truth_j: f64,
    /// Sum of naive-method energies, joules.
    pub naive_j: f64,
    /// Sum of good-practice energies, joules.
    pub good_j: f64,
    /// Per-node percentage errors (naive, good practice).
    pub node_errors: Vec<(f64, f64)>,
    pub nodes_measured: usize,
    /// Total measured window time across nodes, seconds (Σ per-node
    /// kernel-execution windows). Turns the energy sums back into a
    /// fleet-average draw per GPU.
    pub measured_s: f64,
}

impl FleetReport {
    /// Aggregate per-node outcomes (shared by `Scheduler::run` and the
    /// streaming campaign mode, so both produce identical reports).
    pub fn from_outcomes(outcomes: &[super::scheduler::MeasurementOutcome]) -> Self {
        let mut report = FleetReport::default();
        for o in outcomes {
            report.truth_j += o.truth_j;
            report.naive_j += o.truth_j * (1.0 + o.naive_pct_error / 100.0);
            report.good_j += o.truth_j * (1.0 + o.good_pct_error / 100.0);
            report.measured_s += o.window_s;
            report.node_errors.push((o.naive_pct_error, o.good_pct_error));
        }
        report.nodes_measured = outcomes.len();
        report
    }

    /// Fleet-level percentage error of the naive accounting.
    pub fn naive_pct(&self) -> f64 {
        100.0 * (self.naive_j - self.truth_j) / self.truth_j
    }

    /// Fleet-level percentage error of the good-practice accounting.
    pub fn good_pct(&self) -> f64 {
        100.0 * (self.good_j - self.truth_j) / self.truth_j
    }

    /// Time-weighted mean ground-truth draw per measured GPU, watts:
    /// `Σ energy / Σ window time` over the measured nodes.
    pub fn mean_node_power_w(&self) -> f64 {
        if self.measured_s <= 0.0 {
            0.0
        } else {
            self.truth_j / self.measured_s
        }
    }

    /// The naive method's accounting error per GPU, watts: the fractional
    /// energy error applied to the fleet's measured mean draw.
    pub fn err_w_per_gpu(&self) -> f64 {
        if self.truth_j <= 0.0 {
            return 0.0;
        }
        (self.naive_j - self.truth_j) / self.truth_j * self.mean_node_power_w()
    }

    /// Annualised cost error in USD for a fleet scaled to `n_gpus`,
    /// assuming the measured-window power mix is representative and
    /// `usd_per_kwh` electricity (the paper's $1M/year example). The
    /// per-GPU mean draw is derived from the measured energies and window
    /// durations — not a hard-coded guess.
    pub fn annual_cost_error_usd(&self, n_gpus: usize, usd_per_kwh: f64) -> f64 {
        if self.truth_j <= 0.0 || self.nodes_measured == 0 || self.measured_s <= 0.0 {
            return 0.0;
        }
        let kwh_year = crate::units::w_to_kwh_per_year(self.err_w_per_gpu().abs());
        kwh_year * usd_per_kwh * n_gpus as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_respects_size_and_filter() {
        let f = Fleet::build(FleetConfig {
            size: 32,
            models: vec!["A100".into()],
            ..Default::default()
        });
        assert_eq!(f.len(), 32);
        assert!(f.nodes.iter().all(|n| n.device.model.name.contains("A100")));
    }

    #[test]
    fn mixed_fleet_has_variety() {
        let f = Fleet::build(FleetConfig { size: 200, ..Default::default() });
        let distinct: std::collections::HashSet<&str> =
            f.nodes.iter().map(|n| n.device.model.name).collect();
        assert!(distinct.len() > 5, "got {} distinct models", distinct.len());
    }

    #[test]
    fn nodes_have_distinct_tolerances() {
        let f = Fleet::build(FleetConfig { size: 10, models: vec!["3090".into()], ..Default::default() });
        let g0 = f.nodes[0].device.tolerance.gradient;
        assert!(f.nodes.iter().skip(1).any(|n| n.device.tolerance.gradient != g0));
    }

    #[test]
    #[should_panic]
    fn empty_filter_panics() {
        Fleet::build(FleetConfig { models: vec!["no-such-gpu".into()], ..Default::default() });
    }

    #[test]
    fn cost_error_scales_with_fleet() {
        // 3000 J of truth over 10 s of measured windows -> 300 W mean draw;
        // naive overcounts by 5% -> 15 W per GPU, year-round
        let r = FleetReport {
            truth_j: 3000.0,
            naive_j: 3150.0,
            good_j: 3030.0,
            node_errors: vec![],
            nodes_measured: 10,
            measured_s: 10.0,
        };
        assert!((r.mean_node_power_w() - 300.0).abs() < 1e-9);
        assert!((r.err_w_per_gpu() - 15.0).abs() < 1e-9);
        let c10k = r.annual_cost_error_usd(10_000, 0.15);
        let c1k = r.annual_cost_error_usd(1_000, 0.15);
        assert!((c10k / c1k - 10.0).abs() < 1e-9);
        // 15 W * 8760 h = 131.4 kWh/GPU-year -> $19.71/GPU-year at $0.15
        assert!((c10k - 15.0 * 8.760 * 0.15 * 10_000.0).abs() < 1.0, "c10k = {c10k}");
        assert!(c10k > 100_000.0, "5% of 10k GPUs is real money: {c10k}");
    }

    #[test]
    fn cost_error_tracks_measured_draw_not_a_constant() {
        // same fractional error, half the mean draw -> half the cost error
        let hot = FleetReport {
            truth_j: 3000.0,
            naive_j: 3150.0,
            good_j: 3000.0,
            node_errors: vec![],
            nodes_measured: 5,
            measured_s: 10.0,
        };
        let cool = FleetReport { measured_s: 20.0, ..hot.clone() };
        let c_hot = hot.annual_cost_error_usd(1_000, 0.15);
        let c_cool = cool.annual_cost_error_usd(1_000, 0.15);
        assert!((c_hot / c_cool - 2.0).abs() < 1e-9, "{c_hot} vs {c_cool}");
    }

    #[test]
    fn cost_error_degenerate_reports_are_zero() {
        let empty = FleetReport::default();
        assert_eq!(empty.annual_cost_error_usd(10_000, 0.15), 0.0);
        assert_eq!(empty.mean_node_power_w(), 0.0);
        assert_eq!(empty.err_w_per_gpu(), 0.0);
    }
}
