//! A simulated datacenter fleet of GPUs.

use crate::rng::Rng;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{DriverEpoch, GpuModel, PowerField, CATALOGUE};

/// Fleet composition config.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of cards.
    pub size: usize,
    /// Restrict to these model-name substrings (empty = whole catalogue,
    /// weighted by the paper's tested counts).
    pub models: Vec<String>,
    /// Driver epoch for every node.
    pub driver: DriverEpoch,
    /// Power field queried by the telemetry collector.
    pub field: PowerField,
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            size: 64,
            models: Vec::new(),
            driver: DriverEpoch::Post530,
            field: PowerField::Draw,
            seed: 7,
        }
    }
}

/// One fleet node.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub device: GpuDevice,
}

/// The instantiated fleet.
#[derive(Debug)]
pub struct Fleet {
    pub nodes: Vec<Node>,
    pub config: FleetConfig,
}

impl Fleet {
    /// Build a fleet: models drawn from the catalogue proportionally to the
    /// paper's tested counts (or the filtered subset).
    pub fn build(config: FleetConfig) -> Self {
        let pool: Vec<&'static GpuModel> = if config.models.is_empty() {
            CATALOGUE.iter().collect()
        } else {
            CATALOGUE
                .iter()
                .filter(|m| {
                    config
                        .models
                        .iter()
                        .any(|q| m.name.to_lowercase().contains(&q.to_lowercase()))
                })
                .collect()
        };
        assert!(!pool.is_empty(), "no models matched the fleet filter");
        // weighted by tested_count
        let weights: Vec<u32> = pool.iter().map(|m| m.tested_count.max(1)).collect();
        let total: u32 = weights.iter().sum();
        let mut rng = Rng::new(config.seed);
        let nodes = (0..config.size)
            .map(|id| {
                let mut pick = rng.below(total as u64) as u32;
                let mut model = pool[0];
                for (m, w) in pool.iter().zip(&weights) {
                    if pick < *w {
                        model = m;
                        break;
                    }
                    pick -= w;
                }
                Node { id, device: GpuDevice::new(model, id as u32, config.seed) }
            })
            .collect();
        Fleet { nodes, config }
    }

    /// Fleet size.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Aggregated fleet measurement report.
#[derive(Debug, Clone, Default)]
pub struct FleetReport {
    /// Sum of ground-truth energies, joules.
    pub truth_j: f64,
    /// Sum of naive-method energies, joules.
    pub naive_j: f64,
    /// Sum of good-practice energies, joules.
    pub good_j: f64,
    /// Per-node percentage errors (naive, good practice).
    pub node_errors: Vec<(f64, f64)>,
    pub nodes_measured: usize,
}

impl FleetReport {
    /// Fleet-level percentage error of the naive accounting.
    pub fn naive_pct(&self) -> f64 {
        100.0 * (self.naive_j - self.truth_j) / self.truth_j
    }

    /// Fleet-level percentage error of the good-practice accounting.
    pub fn good_pct(&self) -> f64 {
        100.0 * (self.good_j - self.truth_j) / self.truth_j
    }

    /// Annualised cost error in USD for a fleet scaled to `n_gpus`,
    /// assuming the measured-window power mix is representative and
    /// `usd_per_kwh` electricity (the paper's $1M/year example).
    pub fn annual_cost_error_usd(&self, n_gpus: usize, usd_per_kwh: f64) -> f64 {
        if self.truth_j <= 0.0 || self.nodes_measured == 0 {
            return 0.0;
        }
        let err_w_per_gpu = (self.naive_j - self.truth_j) / self.truth_j
            * (self.truth_j / self.nodes_measured as f64); // J error per GPU over the window
        // scale: J error per measured second per GPU → W → kWh/year
        let _ = err_w_per_gpu;
        let frac_err = (self.naive_j - self.truth_j) / self.truth_j;
        let mean_w = 300.0; // representative data-center GPU draw
        let kwh_year = mean_w * 24.0 * 365.0 / 1000.0;
        frac_err.abs() * kwh_year * usd_per_kwh * n_gpus as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_respects_size_and_filter() {
        let f = Fleet::build(FleetConfig {
            size: 32,
            models: vec!["A100".into()],
            ..Default::default()
        });
        assert_eq!(f.len(), 32);
        assert!(f.nodes.iter().all(|n| n.device.model.name.contains("A100")));
    }

    #[test]
    fn mixed_fleet_has_variety() {
        let f = Fleet::build(FleetConfig { size: 200, ..Default::default() });
        let distinct: std::collections::HashSet<&str> =
            f.nodes.iter().map(|n| n.device.model.name).collect();
        assert!(distinct.len() > 5, "got {} distinct models", distinct.len());
    }

    #[test]
    fn nodes_have_distinct_tolerances() {
        let f = Fleet::build(FleetConfig { size: 10, models: vec!["3090".into()], ..Default::default() });
        let g0 = f.nodes[0].device.tolerance.gradient;
        assert!(f.nodes.iter().skip(1).any(|n| n.device.tolerance.gradient != g0));
    }

    #[test]
    #[should_panic]
    fn empty_filter_panics() {
        Fleet::build(FleetConfig { models: vec!["no-such-gpu".into()], ..Default::default() });
    }

    #[test]
    fn cost_error_scales_with_fleet() {
        let r = FleetReport { truth_j: 1000.0, naive_j: 1050.0, good_j: 1010.0, node_errors: vec![], nodes_measured: 10 };
        let c10k = r.annual_cost_error_usd(10_000, 0.15);
        let c1k = r.annual_cost_error_usd(1_000, 0.15);
        assert!((c10k / c1k - 10.0).abs() < 1e-9);
        assert!(c10k > 100_000.0, "5% of 10k GPUs is real money: {c10k}");
    }
}
