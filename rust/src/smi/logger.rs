//! Polling logger: `nvidia-smi --query-gpu=... -lms <period>` emulation.
//!
//! The CLI's actual query period "can deviate by several milliseconds"
//! (paper §4.1); the poller reproduces that jitter so the update-period
//! histogram experiment (Fig. 6) sees realistic data.

use super::NvidiaSmi;
use crate::rng::Rng;
use crate::sim::profile::PowerField;
use crate::sim::sensor::{value_at_readings, Reading};
use crate::sim::trace::SampleSeries;

/// Threshold below which two reported values count as "the same
/// publication": nvidia-smi prints 0.01 W resolution, so any genuine
/// republication differs by at least half a quantum. Shared with the
/// telemetry registry's online update-period identification so the two
/// change-detection scans can never diverge.
pub const VALUE_CHANGE_EPS: f64 = 1e-9;

/// A captured polling session.
#[derive(Debug, Clone, Default)]
pub struct PollLog {
    /// (query time, reported watts); unsupported queries are skipped.
    pub series: SampleSeries,
    /// Requested cadence, seconds.
    pub period_s: f64,
}

impl PollLog {
    /// Lengths (in consecutive queries) of runs with an identical reported
    /// value — the paper's method for measuring the power update period.
    pub fn constant_run_lengths(&self) -> Vec<usize> {
        let mut runs = Vec::new();
        let pts = &self.series.points;
        if pts.is_empty() {
            return runs;
        }
        let mut len = 1usize;
        for w in pts.windows(2) {
            if (w[1].1 - w[0].1).abs() < VALUE_CHANGE_EPS {
                len += 1;
            } else {
                runs.push(len);
                len = 1;
            }
        }
        runs.push(len);
        runs
    }

    /// Durations (seconds) between value *changes* — the observable power
    /// update periods.
    pub fn update_periods(&self) -> Vec<f64> {
        let pts = &self.series.points;
        let mut out = Vec::new();
        let mut last_change_t = match pts.first() {
            Some(p) => p.0,
            None => return out,
        };
        for w in pts.windows(2) {
            if (w[1].1 - w[0].1).abs() >= VALUE_CHANGE_EPS {
                out.push(w[1].0 - last_change_t);
                last_change_t = w[1].0;
            }
        }
        out
    }
}

/// Fixed-cadence poller with realistic timing jitter.
#[derive(Debug, Clone, Copy)]
pub struct Poller {
    pub period_s: f64,
    /// Jitter std-dev as a fraction of the period (clamped at ±3 ms).
    pub jitter_frac: f64,
}

impl Poller {
    pub fn new(period_s: f64) -> Self {
        Poller { period_s, jitter_frac: 0.15 }
    }

    /// Poll `field` from `t0` to `t1`.
    pub fn run(&self, smi: &NvidiaSmi, field: PowerField, t0: f64, t1: f64) -> PollLog {
        let mut points = Vec::new();
        poll_readings(
            &smi.stream(field).readings,
            smi.query_rng(),
            self.period_s,
            self.jitter_frac,
            t0,
            t1,
            &mut points,
        );
        PollLog { series: SampleSeries { points }, period_s: self.period_s }
    }
}

/// The polling loop itself, over a raw readings slice: shared by
/// [`Poller::run`] and the streaming measurement path (which polls
/// scratch-buffer readings without constructing an `NvidiaSmi`). Appends
/// `(query time, watts)` pairs to `out`; unsupported/early queries are
/// skipped exactly like the CLI's `[N/A]` rows.
pub fn poll_readings(
    readings: &[Reading],
    mut rng: Rng,
    period_s: f64,
    jitter_frac: f64,
    t0: f64,
    t1: f64,
    out: &mut Vec<(f64, f64)>,
) {
    let mut t = t0;
    while t < t1 {
        if let Some(w) = value_at_readings(readings, t) {
            out.push((t, w));
        }
        let jitter = rng.normal_ms(0.0, period_s * jitter_frac).clamp(-0.003, 0.003);
        t += (period_s + jitter).max(period_s * 0.25);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::activity::ActivitySignal;
    use crate::sim::device::GpuDevice;
    use crate::sim::profile::{find_model, DriverEpoch};

    fn smi() -> NvidiaSmi {
        let device = GpuDevice::new(find_model("V100 PCIe").unwrap(), 0, 11);
        // square wave so values actually change between updates
        let act = ActivitySignal::square_wave(0.2, 0.02, 0.5, 1.0, 200);
        let truth = device.synthesize(&act, 0.0, 5.0);
        NvidiaSmi::attach(device, DriverEpoch::Pre530, &truth, 999)
    }

    #[test]
    fn poll_count_matches_cadence() {
        let s = smi();
        let log = s.poll(PowerField::Draw, 0.005, 0.0, 5.0);
        // 5 s at 5 ms -> ~1000 queries, allow jitter slack
        assert!((900..=1100).contains(&log.series.points.len()), "{}", log.series.points.len());
    }

    #[test]
    fn run_lengths_reflect_update_period() {
        // V100: 20 ms update period, polled at 5 ms -> runs of ~4
        let s = smi();
        let log = s.poll(PowerField::Draw, 0.005, 0.5, 4.5);
        let mut runs = log.constant_run_lengths();
        runs.sort_unstable();
        let med = runs[runs.len() / 2];
        assert!((3..=5).contains(&med), "median run {med}");
    }

    #[test]
    fn update_periods_median_20ms() {
        let s = smi();
        let log = s.poll(PowerField::Draw, 0.002, 0.5, 4.5);
        let mut p = log.update_periods();
        assert!(!p.is_empty());
        p.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = p[p.len() / 2];
        assert!((med - 0.020).abs() < 0.005, "median update period {med}");
    }

    #[test]
    fn empty_log_no_panic() {
        let log = PollLog::default();
        assert!(log.constant_run_lengths().is_empty());
        assert!(log.update_periods().is_empty());
    }

    fn log_of(points: &[(f64, f64)]) -> PollLog {
        PollLog { series: SampleSeries { points: points.to_vec() }, period_s: 0.01 }
    }

    #[test]
    fn single_point_is_one_run_no_periods() {
        let log = log_of(&[(0.5, 100.0)]);
        assert_eq!(log.constant_run_lengths(), vec![1]);
        assert!(log.update_periods().is_empty());
    }

    #[test]
    fn all_identical_readings_are_one_run() {
        let log = log_of(&[(0.0, 250.0), (0.01, 250.0), (0.02, 250.0), (0.03, 250.0)]);
        assert_eq!(log.constant_run_lengths(), vec![4]);
        assert!(log.update_periods().is_empty(), "no value ever changes");
    }

    #[test]
    fn epsilon_threshold_splits_runs_exactly() {
        // |Δ| < 1e-9 counts as "same value"; |Δ| >= 1e-9 is a change
        let below = log_of(&[(0.0, 100.0), (0.01, 100.0 + 0.9e-9)]);
        assert_eq!(below.constant_run_lengths(), vec![2]);
        assert!(below.update_periods().is_empty());

        let at = log_of(&[(0.0, 100.0), (0.01, 100.0 + 1.5e-9), (0.03, 100.0 + 3e-9)]);
        assert_eq!(at.constant_run_lengths(), vec![1, 1, 1]);
        let p = at.update_periods();
        assert_eq!(p.len(), 2);
        assert!((p[0] - 0.01).abs() < 1e-12);
        assert!((p[1] - 0.02).abs() < 1e-12);
    }

    #[test]
    fn runs_and_periods_agree_on_change_count() {
        // n runs <=> n-1 changes <=> n-1 update periods
        let log = log_of(&[
            (0.00, 100.0),
            (0.01, 100.0),
            (0.02, 140.0),
            (0.03, 140.0),
            (0.04, 90.0),
            (0.05, 90.0),
        ]);
        let runs = log.constant_run_lengths();
        assert_eq!(runs, vec![2, 2, 2]);
        assert_eq!(log.update_periods().len(), runs.len() - 1);
    }
}
