//! `nvidia-smi` emulation: the query surface the paper studies (§2.4).
//!
//! A [`NvidiaSmi`] binds a simulated card + driver epoch to a captured
//! ground-truth trace, realises the internal sensor streams for every power
//! field, and answers queries exactly like the CLI: the reported value is
//! the last *published* reading, held constant between updates, with query
//! timestamps jittering by a few milliseconds around the requested cadence.

//!
//! [`schemas`] extends the recorded-log surface beyond nvidia-smi CSV to
//! the foreign telemetry zoo (NVML mW logs, amdsmi CSV, DCGM/Prometheus
//! scrapes, IPMI host rails), each normalising into [`SmiLog`] so the
//! replay pipeline ingests every vendor unchanged.

pub mod cli;
pub mod energy_counter;
pub mod logger;
pub mod schemas;

pub use cli::{
    format_log, format_row, parse_header, parse_log, parse_query, LogValue, QueryField, SmiLog,
};
pub use energy_counter::{run_counter, CounterDesign, EnergyCounter};
pub use logger::{poll_readings, PollLog, Poller};
pub use schemas::SchemaKind;

use crate::rng::Rng;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{sensor_pipeline, DriverEpoch, PowerField};
use crate::sim::sensor::{run_pipeline, SensorStream};
use crate::sim::trace::PowerTrace;

/// An nvidia-smi instance attached to one simulated GPU.
#[derive(Debug)]
pub struct NvidiaSmi {
    pub device: GpuDevice,
    pub driver: DriverEpoch,
    /// Boot seed: fixes the unobservable sensor phase for this boot.
    pub boot_seed: u64,
    streams: Vec<(PowerField, SensorStream)>,
    truth_t_end: f64,
}

impl NvidiaSmi {
    /// "Boot" the driver against a ground-truth power capture: realise the
    /// internal sensor stream for each supported field.
    pub fn attach(device: GpuDevice, driver: DriverEpoch, truth: &PowerTrace, boot_seed: u64) -> Self {
        let mut streams = Vec::new();
        for field in PowerField::ALL {
            let spec = sensor_pipeline(device.model.generation, field, driver);
            let stream = run_pipeline(&device, spec, truth, boot_seed ^ field_tag(field));
            streams.push((field, stream));
        }
        NvidiaSmi { device, driver, boot_seed, streams, truth_t_end: truth.t_end() }
    }

    /// The realised internal stream for a field (what the paper's
    /// experiments reverse-engineer).
    pub fn stream(&self, field: PowerField) -> &SensorStream {
        &self.streams.iter().find(|(f, _)| *f == field).unwrap().1
    }

    /// Query a power field at time `t`, like
    /// `nvidia-smi --query-gpu=power.draw`. `None` when the field/driver
    /// combination is unsupported ("[N/A]") or before the first update.
    pub fn query(&self, field: PowerField, t: f64) -> Option<f64> {
        self.stream(field).value_at(t)
    }

    /// Poll a field at a fixed cadence over a window, with realistic
    /// query-time jitter ("the actual period can deviate by several
    /// milliseconds", §4.1).
    pub fn poll(&self, field: PowerField, period_s: f64, t0: f64, t1: f64) -> PollLog {
        Poller::new(period_s).run(self, field, t0, t1)
    }

    /// End of the attached capture.
    pub fn t_end(&self) -> f64 {
        self.truth_t_end
    }

    /// Per-boot RNG for query jitter, derived from the boot seed.
    pub(crate) fn query_rng(&self) -> Rng {
        Rng::new(self.boot_seed ^ 0x5149)
    }
}

/// Per-field RNG tag: each field's sensor stream derives an independent
/// boot seed, so realising only one field (the streaming measurement path)
/// yields bit-for-bit the same readings as realising all three.
pub(crate) fn field_tag(field: PowerField) -> u64 {
    match field {
        PowerField::Draw => 0x11,
        PowerField::Average => 0x22,
        PowerField::Instant => 0x33,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::activity::ActivitySignal;
    use crate::sim::profile::find_model;

    fn smi_for(model: &str, driver: DriverEpoch) -> NvidiaSmi {
        let device = GpuDevice::new(find_model(model).unwrap(), 0, 321);
        let act = ActivitySignal::burst(1.0, 2.0, 1.0);
        let truth = device.synthesize(&act, 0.0, 4.0);
        NvidiaSmi::attach(device, driver, &truth, 555)
    }

    #[test]
    fn query_returns_plausible_power() {
        let smi = smi_for("RTX 3090", DriverEpoch::Post530);
        let w = smi.query(PowerField::Instant, 2.5).unwrap();
        assert!(w > 250.0 && w < 450.0, "w={w}");
    }

    #[test]
    fn old_driver_lacks_new_fields() {
        let smi = smi_for("RTX 3090", DriverEpoch::Pre530);
        assert!(smi.query(PowerField::Instant, 2.0).is_none());
        assert!(smi.query(PowerField::Average, 2.0).is_none());
        assert!(smi.query(PowerField::Draw, 2.0).is_some());
    }

    #[test]
    fn fermi_reports_nothing() {
        let smi = smi_for("C2050", DriverEpoch::Pre530);
        assert!(smi.query(PowerField::Draw, 2.0).is_none());
    }

    #[test]
    fn value_held_between_updates() {
        let smi = smi_for("RTX 3090", DriverEpoch::Post530);
        // two queries 1 ms apart almost surely fall in the same 100 ms update
        let a = smi.query(PowerField::Draw, 2.0500).unwrap();
        let b = smi.query(PowerField::Draw, 2.0510).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn average_lags_instant_after_step() {
        // post-530 H100: instant (25 ms window) reaches steady state long
        // before average (1 s window)
        let smi = smi_for("H100", DriverEpoch::Post530);
        let t = 1.35; // 350 ms after the step
        let inst = smi.query(PowerField::Instant, t).unwrap();
        let avg = smi.query(PowerField::Average, t).unwrap();
        assert!(inst > avg + 30.0, "inst={inst} avg={avg}");
    }
}
