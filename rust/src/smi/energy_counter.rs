//! Extension experiment: the NVML total-energy counter
//! (`nvmlDeviceGetTotalEnergyConsumption`, Volta+).
//!
//! The paper's future-work question is whether the millijoule counter
//! sidesteps the "part-time" power problem. We model both designs found in
//! the field:
//!   * a counter that integrates the *full-rate internal* sensor
//!     (continuous integration — the ideal case), and
//!   * a counter that integrates the same *windowed* samples the power
//!     field reports (inherits the A100's 75% blindness).
//! The `experiments::ablations` module compares them against the PMD.

use crate::sim::device::GpuDevice;
use crate::sim::profile::PipelineSpec;
use crate::sim::trace::PowerTrace;

/// Which internal signal the counter integrates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CounterDesign {
    /// Integrates the true board power continuously (ideal).
    Continuous,
    /// Integrates one boxcar sample per update period (windowed).
    Windowed,
}

/// An NVML-style monotonically-increasing energy counter, millijoules.
#[derive(Debug, Clone)]
pub struct EnergyCounter {
    pub design: CounterDesign,
    /// (time, mJ since boot) — counter values at update instants.
    pub samples: Vec<(f64, u64)>,
}

/// Realise the counter over a ground-truth capture.
pub fn run_counter(
    device: &GpuDevice,
    spec: PipelineSpec,
    truth: &PowerTrace,
    design: CounterDesign,
) -> EnergyCounter {
    let update_s = crate::units::ms_to_s(spec.update_ms);
    let window_s = match spec.kind {
        crate::sim::profile::PipelineKind::Boxcar { window_ms } => crate::units::ms_to_s(window_ms),
        _ => update_s,
    };
    let prefix = truth.prefix_sums();
    let mut samples = Vec::new();
    let mut acc_mj = 0.0f64;
    let mut t = truth.t0 + update_s;
    let mut t_prev = truth.t0;
    while t < truth.t_end() {
        let p = match design {
            // continuous: the true mean power over the whole update interval
            CounterDesign::Continuous => truth.window_mean_with(&prefix, t, t - t_prev),
            // windowed: only the trailing window is visible
            CounterDesign::Windowed => truth.window_mean_with(&prefix, t, window_s),
        };
        acc_mj += device.tolerance.apply(p) * (t - t_prev) * 1000.0;
        samples.push((t, acc_mj as u64));
        t_prev = t;
        t += update_s;
    }
    EnergyCounter { design, samples }
}

impl EnergyCounter {
    /// Energy between two times, joules (reads the counter like a client
    /// would: difference of the latest samples at each time).
    pub fn energy_between_j(&self, t0: f64, t1: f64) -> f64 {
        let at = |t: f64| -> u64 {
            self.samples
                .iter()
                .take_while(|(ts, _)| *ts <= t)
                .last()
                .map(|(_, mj)| *mj)
                .unwrap_or(0)
        };
        (at(t1).saturating_sub(at(t0))) as f64 / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::activity::ActivitySignal;
    use crate::sim::profile::find_model;

    fn capture() -> (GpuDevice, PowerTrace) {
        let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, 91);
        // aliased square wave: the adversarial case for the 25/100 window
        let act = ActivitySignal::square_wave(0.5, 0.1004, 0.5, 1.0, 60);
        let truth = device.synthesize(&act, 0.0, 7.0);
        (device, truth)
    }

    #[test]
    fn counter_is_monotonic() {
        let (device, truth) = capture();
        for design in [CounterDesign::Continuous, CounterDesign::Windowed] {
            let c = run_counter(&device, PipelineSpec::boxcar(100.0, 25.0), &truth, design);
            assert!(c.samples.windows(2).all(|w| w[1].1 >= w[0].1), "{design:?}");
            assert!(c.samples.len() > 60);
        }
    }

    #[test]
    fn continuous_counter_beats_windowed_on_a100() {
        // the paper-shaped result: a counter that integrates continuously is
        // immune to the 25/100 blindness; one that integrates windowed
        // samples inherits it
        let (device, truth) = capture();
        let spec = PipelineSpec::boxcar(100.0, 25.0);
        let cont = run_counter(&device, spec, &truth, CounterDesign::Continuous);
        let wind = run_counter(&device, spec, &truth, CounterDesign::Windowed);
        let want = device.tolerance.apply(truth.energy_between(1.0, 6.0) / 5.0) * 5.0;
        let e_c = cont.energy_between_j(1.0, 6.0);
        let e_w = wind.energy_between_j(1.0, 6.0);
        let err = |e: f64| 100.0 * (e - want).abs() / want;
        assert!(err(e_c) < 2.0, "continuous err {:.2}%", err(e_c));
        assert!(err(e_c) < err(e_w), "continuous {:.2}% !< windowed {:.2}%", err(e_c), err(e_w));
    }

    #[test]
    fn energy_between_handles_out_of_range() {
        let (device, truth) = capture();
        let c = run_counter(&device, PipelineSpec::boxcar(100.0, 25.0), &truth, CounterDesign::Continuous);
        assert_eq!(c.energy_between_j(-5.0, -1.0), 0.0);
        assert!(c.energy_between_j(0.0, 100.0) > 0.0);
    }
}
