//! nvidia-smi-compatible text output: `--query-gpu=... --format=csv`.
//!
//! The emulation is usable as a drop-in data source for tooling that
//! parses nvidia-smi CSV logs (CarbonTracker-style collectors, §7): the
//! same field names, the same `[N/A]` convention, the same two-decimal
//! watt formatting.

use super::NvidiaSmi;
use crate::sim::profile::PowerField;

/// A parsed `--query-gpu` field list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryField {
    Name,
    PowerDraw,
    PowerDrawAverage,
    PowerDrawInstant,
    PowerLimit,
    Timestamp,
}

impl QueryField {
    /// Parse one field name as nvidia-smi spells it.
    pub fn parse(s: &str) -> Option<QueryField> {
        match s.trim() {
            "name" => Some(QueryField::Name),
            "power.draw" => Some(QueryField::PowerDraw),
            "power.draw.average" => Some(QueryField::PowerDrawAverage),
            "power.draw.instant" => Some(QueryField::PowerDrawInstant),
            "power.limit" => Some(QueryField::PowerLimit),
            "timestamp" => Some(QueryField::Timestamp),
            _ => None,
        }
    }

    /// CSV header, as nvidia-smi prints it.
    pub fn header(&self) -> &'static str {
        match self {
            QueryField::Name => "name",
            QueryField::PowerDraw => "power.draw [W]",
            QueryField::PowerDrawAverage => "power.draw.average [W]",
            QueryField::PowerDrawInstant => "power.draw.instant [W]",
            QueryField::PowerLimit => "power.limit [W]",
            QueryField::Timestamp => "timestamp",
        }
    }
}

/// Parse a full `--query-gpu=a,b,c` list; unknown fields are an error,
/// like the real CLI.
pub fn parse_query(list: &str) -> Result<Vec<QueryField>, String> {
    list.split(',')
        .map(|f| QueryField::parse(f).ok_or_else(|| format!("Field \"{}\" is not a valid field to query.", f.trim())))
        .collect()
}

/// Render one CSV row at simulation time `t`.
pub fn format_row(smi: &NvidiaSmi, fields: &[QueryField], t: f64) -> String {
    fields
        .iter()
        .map(|f| match f {
            QueryField::Name => smi.device.model.name.to_string(),
            QueryField::PowerDraw => watt(smi.query(PowerField::Draw, t)),
            QueryField::PowerDrawAverage => watt(smi.query(PowerField::Average, t)),
            QueryField::PowerDrawInstant => watt(smi.query(PowerField::Instant, t)),
            QueryField::PowerLimit => format!("{:.2} W", smi.device.model.power_limit_w),
            QueryField::Timestamp => format!("{t:.3}"),
        })
        .collect::<Vec<_>>()
        .join(", ")
}

fn watt(v: Option<f64>) -> String {
    match v {
        Some(w) => format!("{w:.2} W"),
        None => "[N/A]".to_string(),
    }
}

/// Full CSV log: header + one row per polling instant (`-lms` emulation).
pub fn format_log(smi: &NvidiaSmi, fields: &[QueryField], period_s: f64, t0: f64, t1: f64) -> String {
    let mut out = String::new();
    out.push_str(&fields.iter().map(|f| f.header()).collect::<Vec<_>>().join(", "));
    out.push('\n');
    let mut t = t0;
    while t < t1 {
        out.push_str(&format_row(smi, fields, t));
        out.push('\n');
        t += period_s;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::activity::ActivitySignal;
    use crate::sim::device::GpuDevice;
    use crate::sim::profile::{find_model, DriverEpoch};

    fn smi(driver: DriverEpoch) -> NvidiaSmi {
        let device = GpuDevice::new(find_model("RTX 3090").unwrap(), 0, 3);
        let truth = device.synthesize(&ActivitySignal::burst(0.5, 2.0, 1.0), 0.0, 3.0);
        NvidiaSmi::attach(device, driver, &truth, 5)
    }

    #[test]
    fn parse_accepts_real_field_names() {
        let q = parse_query("timestamp,name,power.draw,power.draw.instant").unwrap();
        assert_eq!(q.len(), 4);
        assert_eq!(q[2], QueryField::PowerDraw);
    }

    #[test]
    fn parse_rejects_unknown_fields() {
        let e = parse_query("power.draw,bogus.field").unwrap_err();
        assert!(e.contains("bogus.field"));
    }

    #[test]
    fn row_formats_watts_with_two_decimals() {
        let s = smi(DriverEpoch::Post530);
        let fields = parse_query("name,power.draw").unwrap();
        let row = format_row(&s, &fields, 2.0);
        assert!(row.starts_with("RTX 3090, "));
        assert!(row.ends_with(" W"), "{row}");
        let w: f64 = row.split(", ").nth(1).unwrap().trim_end_matches(" W").parse().unwrap();
        assert!(w > 100.0);
    }

    #[test]
    fn unsupported_fields_print_na() {
        let s = smi(DriverEpoch::Pre530);
        let fields = parse_query("power.draw.instant").unwrap();
        assert_eq!(format_row(&s, &fields, 2.0), "[N/A]");
    }

    #[test]
    fn log_has_header_and_rows() {
        let s = smi(DriverEpoch::Post530);
        let fields = parse_query("timestamp,power.draw").unwrap();
        let log = format_log(&s, &fields, 0.1, 0.5, 1.5);
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines[0], "timestamp, power.draw [W]");
        assert_eq!(lines.len(), 11);
    }
}
