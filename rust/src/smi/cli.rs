//! nvidia-smi-compatible text output: `--query-gpu=... --format=csv` —
//! and the matching **parser** for recorded logs.
//!
//! The emulation is usable as a drop-in data source for tooling that
//! parses nvidia-smi CSV logs (CarbonTracker-style collectors, §7): the
//! same field names, the same `[N/A]` convention, the same two-decimal
//! watt formatting. [`parse_log`] inverts [`format_log`] exactly
//! (round-trip pinned by tests for every field combination), which is what
//! lets `telemetry::source::ReplaySource` feed *recorded* nvidia-smi
//! sessions through the same ingestion pipeline as live simulated nodes.
//!
//! Recorded-log schema: a header row naming the queried fields (as printed
//! by `nvidia-smi --format=csv`, e.g. `timestamp, name, power.draw [W]`),
//! then one row per poll. Power cells are either `<watts:.2> W` or
//! `[N/A]`. The timestamp column accepts **either** format:
//!
//! * relative seconds since the recording started (what [`format_log`]
//!   emits, millisecond resolution), or
//! * the real `nvidia-smi --query-gpu=timestamp` wall-clock format
//!   `YYYY/MM/DD HH:MM:SS.mmm` — normalised at parse time to relative
//!   seconds at the **first reading**, so raw recorded sessions replay
//!   without preprocessing (midnight/month/leap-year rollovers included;
//!   re-emission via [`SmiLog::format`] then prints the normalised
//!   relative form). Mixing the two formats in one log is an error.
//!
//! CRLF line endings are accepted; malformed rows fail with their line
//! number.

use super::NvidiaSmi;
use crate::sim::profile::PowerField;

/// A parsed `--query-gpu` field list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueryField {
    Name,
    PowerDraw,
    PowerDrawAverage,
    PowerDrawInstant,
    PowerLimit,
    Timestamp,
}

impl QueryField {
    /// Parse one field name as nvidia-smi spells it.
    pub fn parse(s: &str) -> Option<QueryField> {
        match s.trim() {
            "name" => Some(QueryField::Name),
            "power.draw" => Some(QueryField::PowerDraw),
            "power.draw.average" => Some(QueryField::PowerDrawAverage),
            "power.draw.instant" => Some(QueryField::PowerDrawInstant),
            "power.limit" => Some(QueryField::PowerLimit),
            "timestamp" => Some(QueryField::Timestamp),
            _ => None,
        }
    }

    /// CSV header, as nvidia-smi prints it.
    pub fn header(&self) -> &'static str {
        match self {
            QueryField::Name => "name",
            QueryField::PowerDraw => "power.draw [W]",
            QueryField::PowerDrawAverage => "power.draw.average [W]",
            QueryField::PowerDrawInstant => "power.draw.instant [W]",
            QueryField::PowerLimit => "power.limit [W]",
            QueryField::Timestamp => "timestamp",
        }
    }

    /// The simulator [`PowerField`] a column of this query field was
    /// recorded from — what a replayed log should be *scored against*:
    /// `power.draw` is the epoch-dependent default field,
    /// `power.draw.average` the post-R535 averaged sensor class, and
    /// `power.draw.instant` the post-R535 instantaneous one. `None` for
    /// non-power columns.
    pub fn sensor_field(&self) -> Option<PowerField> {
        match self {
            QueryField::PowerDraw => Some(PowerField::Draw),
            QueryField::PowerDrawAverage => Some(PowerField::Average),
            QueryField::PowerDrawInstant => Some(PowerField::Instant),
            _ => None,
        }
    }
}

/// Parse a full `--query-gpu=a,b,c` list; unknown fields are an error,
/// like the real CLI.
pub fn parse_query(list: &str) -> Result<Vec<QueryField>, String> {
    list.split(',')
        .map(|f| QueryField::parse(f).ok_or_else(|| format!("Field \"{}\" is not a valid field to query.", f.trim())))
        .collect()
}

/// Render one CSV row at simulation time `t`.
pub fn format_row(smi: &NvidiaSmi, fields: &[QueryField], t: f64) -> String {
    fields
        .iter()
        .map(|f| match f {
            QueryField::Name => smi.device.model.name.to_string(),
            QueryField::PowerDraw => watt(smi.query(PowerField::Draw, t)),
            QueryField::PowerDrawAverage => watt(smi.query(PowerField::Average, t)),
            QueryField::PowerDrawInstant => watt(smi.query(PowerField::Instant, t)),
            QueryField::PowerLimit => format!("{:.2} W", smi.device.model.power_limit_w),
            QueryField::Timestamp => format!("{t:.3}"),
        })
        .collect::<Vec<_>>()
        .join(", ")
}

fn watt(v: Option<f64>) -> String {
    match v {
        Some(w) => format!("{w:.2} W"),
        None => "[N/A]".to_string(),
    }
}

/// Full CSV log: header + one row per polling instant (`-lms` emulation).
pub fn format_log(smi: &NvidiaSmi, fields: &[QueryField], period_s: f64, t0: f64, t1: f64) -> String {
    let mut out = String::new();
    out.push_str(&fields.iter().map(|f| f.header()).collect::<Vec<_>>().join(", "));
    out.push('\n');
    let mut t = t0;
    while t < t1 {
        out.push_str(&format_row(smi, fields, t));
        out.push('\n');
        t += period_s;
    }
    out
}

/// One parsed cell of a recorded log (parallel to the header's field).
#[derive(Debug, Clone, PartialEq)]
pub enum LogValue {
    /// `name` column.
    Text(String),
    /// A power column, watts; `None` is nvidia-smi's `[N/A]`.
    Watts(Option<f64>),
    /// `timestamp` column, seconds.
    Seconds(f64),
}

/// A parsed recorded `--query-gpu --format=csv` session.
#[derive(Debug, Clone, PartialEq)]
pub struct SmiLog {
    /// The queried fields, in header order.
    pub fields: Vec<QueryField>,
    /// One entry per data row; `rows[r][c]` parallels `fields[c]`.
    pub rows: Vec<Vec<LogValue>>,
}

/// Parse a header row (`timestamp, name, power.draw [W]`). Accepts both
/// the CSV-header spellings ([`QueryField::header`]) and the bare
/// `--query-gpu` names.
pub fn parse_header(line: &str) -> Result<Vec<QueryField>, String> {
    line.split(',')
        .map(|cell| {
            let cell = cell.trim();
            QueryField::parse(cell)
                .or_else(|| {
                    [
                        QueryField::Name,
                        QueryField::PowerDraw,
                        QueryField::PowerDrawAverage,
                        QueryField::PowerDrawInstant,
                        QueryField::PowerLimit,
                        QueryField::Timestamp,
                    ]
                    .into_iter()
                    .find(|f| f.header() == cell)
                })
                .ok_or_else(|| format!("unknown header field '{cell}'"))
        })
        .collect()
}

/// Days since 1970-01-01 for a civil date (Howard Hinnant's
/// `days_from_civil`; handles leap years and the Gregorian 100/400 rules).
fn days_from_civil(y: i64, m: u32, d: u32) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = (if y >= 0 { y } else { y - 399 }) / 400;
    let yoe = (y - era * 400) as u64; // [0, 399]
    let mp = (m as u64 + 9) % 12; // Mar=0 .. Feb=11
    let doy = (153 * mp + 2) / 5 + d as u64 - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    era * 146_097 + doe as i64
}

/// Days in `m` of year `y` (Gregorian).
fn days_in_month(y: i64, m: u32) -> u32 {
    match m {
        1 | 3 | 5 | 7 | 8 | 10 | 12 => 31,
        4 | 6 | 9 | 11 => 30,
        2 => {
            if y % 4 == 0 && (y % 100 != 0 || y % 400 == 0) {
                29
            } else {
                28
            }
        }
        _ => 0,
    }
}

/// Parse nvidia-smi's wall-clock timestamp (`YYYY/MM/DD HH:MM:SS.mmm`)
/// into absolute seconds since the Unix epoch. `None` when the cell is
/// not in that format or names an impossible calendar date (so the
/// relative-seconds form can be tried first and malformed rows fail with
/// their line number rather than silently shifting).
fn parse_wallclock(cell: &str) -> Option<f64> {
    let (date, time) = cell.split_once(' ')?;
    let mut dp = date.split('/');
    let y: i64 = dp.next()?.parse().ok()?;
    let mo: u32 = dp.next()?.parse().ok()?;
    let dd: u32 = dp.next()?.parse().ok()?;
    if dp.next().is_some() || !(1..=12).contains(&mo) || dd < 1 || dd > days_in_month(y, mo) {
        return None;
    }
    let mut tp = time.split(':');
    let h: u32 = tp.next()?.parse().ok()?;
    let mi: u32 = tp.next()?.parse().ok()?;
    let sec: f64 = tp.next()?.parse().ok()?;
    if tp.next().is_some() || h > 23 || mi > 59 || !(0.0..60.0).contains(&sec) {
        return None;
    }
    let days = days_from_civil(y, mo, dd);
    Some(days as f64 * 86_400.0 + h as f64 * 3_600.0 + mi as f64 * 60.0 + sec)
}

/// Parse a recorded nvidia-smi CSV log. Inverts [`format_log`]: for any
/// log that function emits, `parse_log(log)?.format() == log`. Wall-clock
/// timestamps (the raw nvidia-smi format) are accepted too and normalised
/// to relative seconds at the first reading — parsing such a log is
/// therefore *idempotent* rather than an exact inverse: re-emitting and
/// re-parsing yields the same normalised log. Errors are line-numbered;
/// CRLF endings and blank lines are tolerated.
pub fn parse_log(text: &str) -> Result<SmiLog, String> {
    let mut fields: Option<Vec<QueryField>> = None;
    let mut rows: Vec<Vec<LogValue>> = Vec::new();
    let mut saw_wallclock = false;
    let mut saw_relative = false;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim(); // also strips the '\r' of CRLF input
        if line.is_empty() {
            continue;
        }
        if fields.is_none() {
            fields = Some(parse_header(line).map_err(|e| format!("line {}: {e}", ln + 1))?);
            continue;
        }
        let fields = fields.as_ref().unwrap();
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if cells.len() != fields.len() {
            return Err(format!(
                "line {}: expected {} columns, got {}",
                ln + 1,
                fields.len(),
                cells.len()
            ));
        }
        let mut row = Vec::with_capacity(fields.len());
        for (field, cell) in fields.iter().zip(&cells) {
            row.push(match field {
                QueryField::Name => LogValue::Text(cell.to_string()),
                QueryField::Timestamp => {
                    if let Ok(t) = cell.parse::<f64>() {
                        saw_relative = true;
                        LogValue::Seconds(t)
                    } else if let Some(t) = parse_wallclock(cell) {
                        saw_wallclock = true;
                        LogValue::Seconds(t)
                    } else {
                        return Err(format!("line {}: bad timestamp '{cell}'", ln + 1));
                    }
                }
                _ => {
                    if *cell == "[N/A]" {
                        LogValue::Watts(None)
                    } else {
                        let w = cell
                            .strip_suffix(" W")
                            .ok_or_else(|| {
                                format!("line {}: power cell '{cell}' is not '<watts> W'", ln + 1)
                            })?
                            .parse()
                            .map_err(|_| format!("line {}: bad watts '{cell}'", ln + 1))?;
                        LogValue::Watts(Some(w))
                    }
                }
            });
        }
        rows.push(row);
    }
    let Some(fields) = fields else {
        return Err("log is empty (no header row)".into());
    };
    if saw_wallclock && saw_relative {
        return Err("log mixes wall-clock and relative timestamps".into());
    }
    if saw_wallclock {
        // normalise to relative seconds at the first reading
        let tc = fields
            .iter()
            .position(|f| *f == QueryField::Timestamp)
            .expect("wall-clock timestamps imply a timestamp column");
        let t0 = rows.iter().find_map(|r| match &r[tc] {
            LogValue::Seconds(t) => Some(*t),
            _ => None,
        });
        if let Some(t0) = t0 {
            for row in &mut rows {
                if let LogValue::Seconds(t) = &mut row[tc] {
                    // round to the emitted millisecond resolution so the
                    // normalised log re-emits losslessly
                    *t = crate::units::ms_to_s(crate::units::s_to_ms(*t - t0).round());
                }
            }
        }
    }
    Ok(SmiLog { fields, rows })
}

impl SmiLog {
    /// Re-emit the log in [`format_log`]'s exact format (round-trip pin).
    pub fn format(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.fields.iter().map(|f| f.header()).collect::<Vec<_>>().join(", "));
        out.push('\n');
        for row in &self.rows {
            let rendered: Vec<String> = row
                .iter()
                .map(|v| match v {
                    LogValue::Text(s) => s.clone(),
                    LogValue::Watts(w) => watt(*w),
                    LogValue::Seconds(t) => format!("{t:.3}"),
                })
                .collect();
            out.push_str(&rendered.join(", "));
            out.push('\n');
        }
        out
    }

    /// Column index of `field`, if queried.
    pub fn column(&self, field: &QueryField) -> Option<usize> {
        self.fields.iter().position(|f| f == field)
    }

    /// The first power field the log queried (replay's default column).
    pub fn first_power_field(&self) -> Option<QueryField> {
        self.fields
            .iter()
            .find(|f| {
                matches!(
                    f,
                    QueryField::PowerDraw | QueryField::PowerDrawAverage | QueryField::PowerDrawInstant
                )
            })
            .cloned()
    }

    /// The recorded device name (first row's `name` cell), if present.
    pub fn model_name(&self) -> Option<&str> {
        let c = self.column(&QueryField::Name)?;
        match self.rows.first()?.get(c)? {
            LogValue::Text(s) => Some(s),
            _ => None,
        }
    }

    /// Extract `(timestamp, watts)` readings for one power field into a
    /// caller-owned buffer (cleared first). `[N/A]` rows are skipped, like
    /// a live poller skips unsupported queries. Errors when the log lacks
    /// a timestamp column or the requested field.
    pub fn power_series_into(
        &self,
        field: &QueryField,
        out: &mut Vec<(f64, f64)>,
    ) -> Result<(), String> {
        out.clear();
        let tc = self
            .column(&QueryField::Timestamp)
            .ok_or("log has no timestamp column; replay needs one")?;
        let wc = self
            .column(field)
            .ok_or_else(|| format!("log has no '{}' column", field.header()))?;
        for row in &self.rows {
            let (LogValue::Seconds(t), LogValue::Watts(w)) = (&row[tc], &row[wc]) else {
                continue;
            };
            if let Some(w) = w {
                out.push((*t, *w));
            }
        }
        Ok(())
    }

    /// [`Self::power_series_into`] into a fresh vector.
    pub fn power_series(&self, field: &QueryField) -> Result<Vec<(f64, f64)>, String> {
        let mut out = Vec::new();
        self.power_series_into(field, &mut out)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::activity::ActivitySignal;
    use crate::sim::device::GpuDevice;
    use crate::sim::profile::{find_model, DriverEpoch};

    fn smi(driver: DriverEpoch) -> NvidiaSmi {
        let device = GpuDevice::new(find_model("RTX 3090").unwrap(), 0, 3);
        let truth = device.synthesize(&ActivitySignal::burst(0.5, 2.0, 1.0), 0.0, 3.0);
        NvidiaSmi::attach(device, driver, &truth, 5)
    }

    #[test]
    fn parse_accepts_real_field_names() {
        let q = parse_query("timestamp,name,power.draw,power.draw.instant").unwrap();
        assert_eq!(q.len(), 4);
        assert_eq!(q[2], QueryField::PowerDraw);
    }

    #[test]
    fn parse_rejects_unknown_fields() {
        let e = parse_query("power.draw,bogus.field").unwrap_err();
        assert!(e.contains("bogus.field"));
    }

    #[test]
    fn row_formats_watts_with_two_decimals() {
        let s = smi(DriverEpoch::Post530);
        let fields = parse_query("name,power.draw").unwrap();
        let row = format_row(&s, &fields, 2.0);
        assert!(row.starts_with("RTX 3090, "));
        assert!(row.ends_with(" W"), "{row}");
        let w: f64 = row.split(", ").nth(1).unwrap().trim_end_matches(" W").parse().unwrap();
        assert!(w > 100.0);
    }

    #[test]
    fn unsupported_fields_print_na() {
        let s = smi(DriverEpoch::Pre530);
        let fields = parse_query("power.draw.instant").unwrap();
        assert_eq!(format_row(&s, &fields, 2.0), "[N/A]");
    }

    #[test]
    fn log_has_header_and_rows() {
        let s = smi(DriverEpoch::Post530);
        let fields = parse_query("timestamp,power.draw").unwrap();
        let log = format_log(&s, &fields, 0.1, 0.5, 1.5);
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines[0], "timestamp, power.draw [W]");
        assert_eq!(lines.len(), 11);
    }

    /// Satellite 3: emit → parse → re-emit is the identity for **every**
    /// non-empty combination of query fields, on both a driver epoch where
    /// all fields report and one where instant/average print `[N/A]` —
    /// covering the two-decimal watt formatting and the `[N/A]` convention.
    #[test]
    fn parse_log_roundtrips_every_field_combination() {
        const ALL: [QueryField; 6] = [
            QueryField::Timestamp,
            QueryField::Name,
            QueryField::PowerDraw,
            QueryField::PowerDrawAverage,
            QueryField::PowerDrawInstant,
            QueryField::PowerLimit,
        ];
        for driver in [DriverEpoch::Post530, DriverEpoch::Pre530] {
            let s = smi(driver);
            for mask in 1u32..(1 << ALL.len()) {
                let fields: Vec<QueryField> = ALL
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| mask & (1 << i) != 0)
                    .map(|(_, f)| f.clone())
                    .collect();
                let text = format_log(&s, &fields, 0.13, 0.4, 1.6);
                let parsed = parse_log(&text)
                    .unwrap_or_else(|e| panic!("mask {mask:#b} {driver:?}: {e}\n{text}"));
                assert_eq!(parsed.fields, fields, "mask {mask:#b}");
                assert_eq!(parsed.format(), text, "mask {mask:#b} {driver:?} must round-trip");
            }
        }
    }

    #[test]
    fn parsed_power_series_matches_the_emitted_readings() {
        let s = smi(DriverEpoch::Post530);
        let fields = parse_query("timestamp,name,power.draw").unwrap();
        // end bound off the 0.05 grid so accumulated float error in the
        // emitter's `t += period` loop cannot change the row count
        let text = format_log(&s, &fields, 0.05, 0.3, 2.29);
        let log = parse_log(&text).unwrap();
        assert_eq!(log.model_name(), Some("RTX 3090"));
        assert_eq!(log.first_power_field(), Some(QueryField::PowerDraw));
        let series = log.power_series(&QueryField::PowerDraw).unwrap();
        assert_eq!(series.len(), 40);
        for (k, &(t, w)) in series.iter().enumerate() {
            let t_want = 0.3 + 0.05 * k as f64;
            assert!((t - t_want).abs() < 5e-4, "timestamp {t} vs {t_want}");
            // identical readings: the parsed watts equal the emitted value
            // (the smi query quantised to the printed 0.01 W resolution)
            let emitted = (s.query(PowerField::Draw, t_want).unwrap() * 100.0).round() / 100.0;
            assert!((w - emitted).abs() < 5e-3, "row {k}: {w} vs {emitted}");
        }
    }

    #[test]
    fn na_rows_are_skipped_by_power_series() {
        // pre-530: power.draw.instant prints [N/A] on every row
        let s = smi(DriverEpoch::Pre530);
        let fields = parse_query("timestamp,power.draw.instant").unwrap();
        let log = parse_log(&format_log(&s, &fields, 0.1, 0.5, 1.5)).unwrap();
        assert_eq!(log.rows.len(), 10);
        assert!(log.power_series(&QueryField::PowerDrawInstant).unwrap().is_empty());
    }

    #[test]
    fn parse_log_errors_are_line_numbered() {
        let e = parse_log("timestamp, power.draw [W]\n0.100, 150.00 W\n0.200, oops W\n")
            .unwrap_err();
        assert!(e.contains("line 3"), "{e}");
        let e = parse_log("timestamp, power.draw [W]\n0.100, 150.00 W, extra\n").unwrap_err();
        assert!(e.contains("line 2") && e.contains("columns"), "{e}");
        let e = parse_log("timestamp, bogus [X]\n").unwrap_err();
        assert!(e.contains("line 1") && e.contains("bogus"), "{e}");
        // watts must carry the " W" suffix
        let e = parse_log("power.draw [W]\n150.00\n").unwrap_err();
        assert!(e.contains("not '<watts> W'"), "{e}");
        assert!(parse_log("").is_err());
        assert!(parse_log("   \n\n").is_err());
    }

    /// Satellite: real nvidia-smi wall-clock timestamps are accepted and
    /// normalised to relative seconds at the first reading — including a
    /// midnight rollover — and the result round-trips idempotently.
    #[test]
    fn parse_log_normalises_wallclock_timestamps() {
        let wall = "timestamp, name, power.draw [W]\n\
                    2024/03/14 23:59:58.500, A100 PCIe-40G, 60.00 W\n\
                    2024/03/14 23:59:59.600, A100 PCIe-40G, 61.25 W\n\
                    2024/03/15 00:00:01.100, A100 PCIe-40G, [N/A]\n\
                    2024/03/15 00:00:02.250, A100 PCIe-40G, 62.50 W\n";
        let log = parse_log(wall).unwrap();
        let series = log.power_series(&QueryField::PowerDraw).unwrap();
        assert_eq!(series, vec![(0.0, 60.0), (1.1, 61.25), (3.75, 62.5)]);

        // identical to the equivalent relative-seconds log
        let rel = "timestamp, name, power.draw [W]\n\
                   0.000, A100 PCIe-40G, 60.00 W\n\
                   1.100, A100 PCIe-40G, 61.25 W\n\
                   2.600, A100 PCIe-40G, [N/A]\n\
                   3.750, A100 PCIe-40G, 62.50 W\n";
        assert_eq!(log, parse_log(rel).unwrap());

        // round-trip is idempotent: the re-emission is the normalised
        // relative log, and parsing it again is a fixed point
        let emitted = log.format();
        assert_eq!(emitted, rel);
        assert_eq!(parse_log(&emitted).unwrap(), log);
    }

    #[test]
    fn wallclock_parsing_handles_calendar_rollovers_and_rejects_garbage() {
        // leap-day and month rollover: 2024/02/29 23:59:59 -> 2024/03/01
        let a = parse_wallclock("2024/02/29 23:59:59.000").unwrap();
        let b = parse_wallclock("2024/03/01 00:00:01.000").unwrap();
        assert!((b - a - 2.0).abs() < 1e-6, "leap-day rollover: {}", b - a);
        // year rollover
        let a = parse_wallclock("2023/12/31 23:59:59.900").unwrap();
        let b = parse_wallclock("2024/01/01 00:00:00.100").unwrap();
        assert!((b - a - 0.2).abs() < 1e-6);
        // millisecond resolution survives
        let t = parse_wallclock("2024/03/14 09:26:53.123").unwrap();
        assert!((t % 60.0 - 53.123).abs() < 1e-6);

        assert!(parse_wallclock("2024-03-14 09:26:53.123").is_none(), "wrong separators");
        assert!(parse_wallclock("2024/13/14 09:26:53.123").is_none(), "bad month");
        assert!(parse_wallclock("2024/03/14 24:00:00.000").is_none(), "bad hour");
        assert!(parse_wallclock("2024/03/14").is_none(), "date only");
        // impossible calendar dates are rejected, not silently shifted
        assert!(parse_wallclock("2024/02/31 00:00:00.000").is_none(), "Feb 31");
        assert!(parse_wallclock("2023/02/29 00:00:00.000").is_none(), "non-leap Feb 29");
        assert!(parse_wallclock("2024/04/31 00:00:00.000").is_none(), "Apr 31");
        assert!(parse_wallclock("2100/02/29 00:00:00.000").is_none(), "century non-leap");

        // in a log: a malformed stamp is a line-numbered error, and mixing
        // formats is rejected
        let e = parse_log("timestamp\n2024/03/14 09:26:53.123\n2024-03-14 09:26:54\n")
            .unwrap_err();
        assert!(e.contains("line 3"), "{e}");
        let e = parse_log("timestamp\n0.100\n2024/03/14 09:26:53.123\n").unwrap_err();
        assert!(e.contains("mixes"), "{e}");
    }

    #[test]
    fn parse_log_accepts_crlf_and_bare_header_names() {
        let text = "timestamp, power.draw\r\n0.100, 151.25 W\r\n0.200, [N/A]\r\n";
        let log = parse_log(text).unwrap();
        assert_eq!(log.fields, vec![QueryField::Timestamp, QueryField::PowerDraw]);
        assert_eq!(log.rows.len(), 2);
        let series = log.power_series(&QueryField::PowerDraw).unwrap();
        assert_eq!(series, vec![(0.1, 151.25)]);
        // re-emission normalises to the canonical header spelling
        assert!(log.format().starts_with("timestamp, power.draw [W]\n"));
    }
}
