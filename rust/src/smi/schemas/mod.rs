//! Foreign telemetry schemas: the real-world sensor-log zoo, normalised.
//!
//! The paper's mechanism — part-time sampling behind an averaged,
//! belatedly-updated power register — is not an nvidia-smi quirk; every
//! vendor telemetry path has its own units, cadence, and averaging
//! semantics that must be *identified, not assumed*. This module ingests
//! the four formats the related tooling actually emits:
//!
//! * [`nvml`] — NVML power/utilisation logs: power in **milliwatts**
//!   (`nvmlDeviceGetPowerUsage`), integer util % (vllm-benchmark-style
//!   collectors);
//! * [`amdsmi`] — amdsmi profiler CSV: integer-watt socket power with
//!   literal `N/A` dropouts, gfx activity %, VRAM (LLM-inference-power
//!   profilers);
//! * [`dcgm`] — DCGM/Prometheus text exposition scrapes: timestamped
//!   `DCGM_FI_DEV_POWER_USAGE` samples, float watts against millisecond
//!   epoch stamps;
//! * [`ipmi`] — IPMI host sensor dumps: integer watts per chassis rail
//!   (`Sys Power`, `CPU Power`, `Mem Power`, `GPU Board Power`, …).
//!
//! Each parser is **total** (malformed input yields a line-numbered
//! `Err`, never a panic — pinned by `tests/proptests.rs`), each writer
//! round-trips its canonical text byte-for-byte, and each schema
//! normalises into the canonical recorded-log form
//! ([`crate::smi::SmiLog`]) via [`parse_to_smi`]/[`normalize`] — so the
//! whole replay → identification → accounting pipeline ingests every
//! vendor **unchanged**, and a `.gpck` checkpoint taken over a foreign
//! log restores exactly like one taken over a native log.
//!
//! All unit scaling routes through [`crate::units`]; no `/ 1000.0`
//! appears at any parse site.

pub mod amdsmi;
pub mod dcgm;
pub mod ipmi;
pub mod nvml;

use super::SmiLog;

/// The foreign log formats the CLI can ingest (`--source <kind>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchemaKind {
    /// NVML-style log: power in milliwatts, util % ([`nvml`]).
    Nvml,
    /// amdsmi profiler CSV: integer-watt socket power ([`amdsmi`]).
    Amdsmi,
    /// DCGM/Prometheus exposition scrape ([`dcgm`]).
    Dcgm,
    /// IPMI host-level sensor dump ([`ipmi`]).
    Ipmi,
}

impl SchemaKind {
    /// Every schema, in `--source` flag order.
    pub const ALL: [SchemaKind; 4] =
        [SchemaKind::Nvml, SchemaKind::Amdsmi, SchemaKind::Dcgm, SchemaKind::Ipmi];

    /// Parse a `--source` flag value.
    pub fn from_flag(s: &str) -> Option<SchemaKind> {
        match s {
            "nvml" => Some(SchemaKind::Nvml),
            "amdsmi" => Some(SchemaKind::Amdsmi),
            "dcgm" => Some(SchemaKind::Dcgm),
            "ipmi" => Some(SchemaKind::Ipmi),
            _ => None,
        }
    }

    /// The flag spelling (and human name) of this schema.
    pub fn name(&self) -> &'static str {
        match self {
            SchemaKind::Nvml => "nvml",
            SchemaKind::Amdsmi => "amdsmi",
            SchemaKind::Dcgm => "dcgm",
            SchemaKind::Ipmi => "ipmi",
        }
    }
}

/// Parse foreign-schema `text` and normalise it into the canonical
/// recorded-log form. Errors are line-numbered and prefixed with the
/// schema name so multi-log CLI invocations stay diagnosable.
pub fn parse_to_smi(kind: SchemaKind, text: &str) -> Result<SmiLog, String> {
    let log = match kind {
        SchemaKind::Nvml => nvml::parse_nvml(text)?.to_smi_log(),
        SchemaKind::Amdsmi => amdsmi::parse_amdsmi(text)?.to_smi_log(),
        SchemaKind::Dcgm => dcgm::parse_dcgm(text)?.to_smi_log(),
        SchemaKind::Ipmi => ipmi::parse_ipmi(text)?.to_smi_log()?,
    };
    Ok(log)
}

/// Foreign text → canonical recorded-log text: the normalisation step
/// the CLI applies before handing a foreign log to the unchanged replay
/// pipeline (so checkpoint digests of a foreign run are the digests of
/// its normalised form, identical between fresh start and `--restore`).
pub fn normalize(kind: SchemaKind, text: &str) -> Result<String, String> {
    parse_to_smi(kind, text).map(|log| log.format())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_round_trips() {
        for kind in SchemaKind::ALL {
            assert_eq!(SchemaKind::from_flag(kind.name()), Some(kind));
        }
        assert_eq!(SchemaKind::from_flag("replay"), None);
        assert_eq!(SchemaKind::from_flag("NVML"), None, "flags are lowercase");
    }

    #[test]
    fn normalize_is_idempotent_for_every_schema() {
        // normalising a foreign log yields canonical text; parsing *that*
        // as a canonical log and re-emitting is a fixed point
        let samples = [
            (SchemaKind::Nvml, nvml::NvmlLog::from_series("RTX 3090", &[(0.0, 25.15), (0.1, 300.0)]).format()),
            (SchemaKind::Amdsmi, amdsmi::AmdsmiLog::from_series("Instinct MI210", &[(0.0, 41.0), (0.1, 290.0)]).format()),
            (SchemaKind::Dcgm, dcgm::DcgmScrape::from_series("A100 PCIe-40G", 1_700_000_000_000, &[(0.0, 61.15), (0.1, 240.5)]).format()),
            (SchemaKind::Ipmi, ipmi::IpmiLog::from_gpu_board_series(&[(0.0, 250.0), (0.5, 260.0)]).format()),
        ];
        for (kind, text) in samples {
            let norm = normalize(kind, &text).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            let again = crate::smi::parse_log(&norm).unwrap().format();
            assert_eq!(norm, again, "{kind:?} normalisation must be idempotent");
        }
    }

    #[test]
    fn errors_carry_the_schema_context_via_line_numbers() {
        for kind in SchemaKind::ALL {
            let e = parse_to_smi(kind, "").unwrap_err();
            assert!(!e.is_empty(), "{kind:?} empty input must error");
        }
    }
}
