//! NVML-style power/utilisation logs: power in **milliwatts**.
//!
//! The format real collectors write when they poll
//! `nvmlDeviceGetPowerUsage` (mW) + `nvmlDeviceGetUtilizationRates`
//! (integer %) in a logging thread — a comment preamble naming the
//! device, then one CSV row per poll:
//!
//! ```text
//! # nvml power log v1
//! # device: RTX 3090
//! time_ms, power_mw, util_pct
//! 0, 25150, 4
//! 100, 301230, 98
//! ```
//!
//! Power cells are integer milliwatts or `[N/A]` (a query that failed
//! mid-run); util cells likewise. [`parse_nvml`] inverts
//! [`NvmlLog::format`] byte-for-byte on canonical text; the milliwatt →
//! watt normalisation in [`NvmlLog::to_smi_log`] routes through
//! [`crate::units::mw_to_w`] — the exact conversion site the units
//! satellite exists to protect.

use crate::smi::{LogValue, QueryField, SmiLog};
use crate::units;

/// One polled NVML row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NvmlRow {
    /// Poll time, milliseconds since the log started.
    pub time_ms: u64,
    /// Power draw in milliwatts; `None` is a failed query (`[N/A]`).
    pub power_mw: Option<u64>,
    /// GPU utilisation percent; `None` is `[N/A]`.
    pub util_pct: Option<u32>,
}

/// A parsed NVML-style log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NvmlLog {
    /// Device name from the `# device:` preamble line.
    pub device: String,
    /// Poll rows, in file order.
    pub rows: Vec<NvmlRow>,
}

const HEADER: [&str; 3] = ["time_ms", "power_mw", "util_pct"];

fn parse_opt_u64(cell: &str, ln: usize, what: &str) -> Result<Option<u64>, String> {
    if cell == "[N/A]" {
        return Ok(None);
    }
    cell.parse::<u64>()
        .map(Some)
        .map_err(|_| format!("line {}: bad {what} '{cell}' (integer or [N/A])", ln + 1))
}

/// Parse an NVML-style log. Total: any malformed input yields a
/// line-numbered `Err`. CRLF endings and blank lines are tolerated;
/// unknown `#` comment lines are skipped; the `# device:` line is
/// required (replay needs a model name to score against).
pub fn parse_nvml(text: &str) -> Result<NvmlLog, String> {
    let mut device: Option<String> = None;
    let mut saw_header = false;
    let mut rows = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim(); // also strips the '\r' of CRLF input
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(name) = rest.trim().strip_prefix("device:") {
                device = Some(name.trim().to_string());
            }
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if !saw_header {
            if cells != HEADER {
                return Err(format!(
                    "line {}: expected header '{}', got '{line}'",
                    ln + 1,
                    HEADER.join(", ")
                ));
            }
            saw_header = true;
            continue;
        }
        if cells.len() != HEADER.len() {
            return Err(format!(
                "line {}: expected {} columns, got {}",
                ln + 1,
                HEADER.len(),
                cells.len()
            ));
        }
        let time_ms = cells[0]
            .parse::<u64>()
            .map_err(|_| format!("line {}: bad time_ms '{}'", ln + 1, cells[0]))?;
        let power_mw = parse_opt_u64(cells[1], ln, "power_mw")?;
        let util_pct = parse_opt_u64(cells[2], ln, "util_pct")?.map(|u| u.min(u32::MAX as u64) as u32);
        rows.push(NvmlRow { time_ms, power_mw, util_pct });
    }
    if !saw_header {
        return Err("log is empty (no header row)".into());
    }
    let device = device.ok_or("log names no device (missing '# device:' line)")?;
    Ok(NvmlLog { device, rows })
}

impl NvmlLog {
    /// Re-emit the log in the canonical NVML-style format; inverse of
    /// [`parse_nvml`] on canonical text (byte round-trip pinned by tests).
    pub fn format(&self) -> String {
        let mut out = String::from("# nvml power log v1\n");
        out.push_str(&format!("# device: {}\n", self.device));
        out.push_str(&HEADER.join(", "));
        out.push('\n');
        for r in &self.rows {
            let p = match r.power_mw {
                Some(mw) => mw.to_string(),
                None => "[N/A]".into(),
            };
            let u = match r.util_pct {
                Some(u) => u.to_string(),
                None => "[N/A]".into(),
            };
            out.push_str(&format!("{}, {p}, {u}\n", r.time_ms));
        }
        out
    }

    /// Normalise into the canonical recorded-log form: milliwatts →
    /// watts, milliseconds → seconds, failed queries stay `[N/A]`.
    pub fn to_smi_log(&self) -> SmiLog {
        let fields = vec![QueryField::Timestamp, QueryField::Name, QueryField::PowerDraw];
        let rows = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    LogValue::Seconds(units::ms_to_s(r.time_ms as f64)),
                    LogValue::Text(self.device.clone()),
                    LogValue::Watts(r.power_mw.map(|mw| units::mw_to_w(mw as f64))),
                ]
            })
            .collect();
        SmiLog { fields, rows }
    }

    /// Writer: render a `(seconds, watts)` series as an NVML log for
    /// `device` — the differential-test path (same synthetic trace out
    /// through every schema, back in through the unchanged core).
    /// Quantises to the format's native resolution: integer milliseconds
    /// and integer milliwatts.
    pub fn from_series(device: &str, points: &[(f64, f64)]) -> NvmlLog {
        let rows = points
            .iter()
            .map(|&(t, w)| NvmlRow {
                time_ms: units::s_to_ms(t).round().max(0.0) as u64,
                power_mw: Some(units::w_to_mw(w).round().max(0.0) as u64),
                util_pct: None,
            })
            .collect();
        NvmlLog { device: device.to_string(), rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CANONICAL: &str = "# nvml power log v1\n\
                             # device: RTX 3090\n\
                             time_ms, power_mw, util_pct\n\
                             0, 25150, 4\n\
                             100, [N/A], [N/A]\n\
                             200, 301230, 98\n";

    #[test]
    fn canonical_text_round_trips_byte_for_byte() {
        let log = parse_nvml(CANONICAL).unwrap();
        assert_eq!(log.device, "RTX 3090");
        assert_eq!(log.rows.len(), 3);
        assert_eq!(log.rows[0], NvmlRow { time_ms: 0, power_mw: Some(25_150), util_pct: Some(4) });
        assert_eq!(log.rows[1].power_mw, None);
        assert_eq!(log.format(), CANONICAL);
    }

    #[test]
    fn normalisation_converts_milliwatts_and_milliseconds() {
        let smi = parse_nvml(CANONICAL).unwrap().to_smi_log();
        assert_eq!(smi.model_name(), Some("RTX 3090"));
        let series = smi.power_series(&QueryField::PowerDraw).unwrap();
        // [N/A] row skipped; mW -> W, ms -> s
        assert_eq!(series, vec![(0.0, 25.15), (0.2, 301.23)]);
        // the normalised text is a valid canonical log (idempotent)
        let text = smi.format();
        assert_eq!(crate::smi::parse_log(&text).unwrap().format(), text);
    }

    #[test]
    fn crlf_and_extra_comments_are_tolerated() {
        let text = "# banner\r\n# device: RTX 3090\r\n# interval: 100ms\r\n\
                    time_ms, power_mw, util_pct\r\n\r\n0, 25150, 4\r\n";
        let log = parse_nvml(text).unwrap();
        assert_eq!(log.rows.len(), 1);
        assert_eq!(log.device, "RTX 3090");
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = parse_nvml("# device: X\ntime_ms, power_mw, util_pct\n0, oops, 4\n").unwrap_err();
        assert!(e.contains("line 3") && e.contains("power_mw"), "{e}");
        let e = parse_nvml("# device: X\ntime_ms, power_mw, util_pct\n0, 100\n").unwrap_err();
        assert!(e.contains("line 3") && e.contains("columns"), "{e}");
        let e = parse_nvml("# device: X\nbogus header\n").unwrap_err();
        assert!(e.contains("line 2"), "{e}");
        let e = parse_nvml("time_ms, power_mw, util_pct\n0, 1, 2\n").unwrap_err();
        assert!(e.contains("device"), "{e}");
        assert!(parse_nvml("").is_err());
        assert!(parse_nvml("# device: X\n").is_err(), "no header row");
    }

    #[test]
    fn writer_quantises_to_native_resolution() {
        let log = NvmlLog::from_series("RTX 3090", &[(0.0, 25.1504), (0.1001, 300.0)]);
        assert_eq!(log.rows[0].power_mw, Some(25_150));
        assert_eq!(log.rows[1].time_ms, 100);
        // writer output parses back and round-trips
        let text = log.format();
        assert_eq!(parse_nvml(&text).unwrap(), log);
        // quantisation error bounded by half a milliwatt
        let series = log.to_smi_log().power_series(&QueryField::PowerDraw).unwrap();
        assert!((series[0].1 - 25.1504).abs() <= 0.0005 + 1e-12);
    }
}
