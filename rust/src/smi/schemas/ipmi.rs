//! IPMI host-level sensor dumps: integer watts per chassis power rail.
//!
//! The format an `ipmitool sensor reading`-polling logger dumps — one
//! column per rail (`Sys Power`, `CPU Power`, `Mem Power`,
//! `GPU Board Power`, `Riser 1 Power`, …), one row per poll, integer
//! watts or `N/A` where the BMC returned nothing:
//!
//! ```text
//! time_s,Sys Power,CPU Power,Mem Power,GPU Board Power,Riser 1 Power
//! 0.000,620,184,96,250,12
//! 1.000,933,210,101,N/A,13
//! ```
//!
//! This is the **host** side of the paper's accounting question: the
//! `GPU Board Power` rail measures the whole board from the chassis,
//! with none of the device sensor's part-time averaging — which makes it
//! the reconciliation reference
//! ([`crate::telemetry::query::host_reconciliation_table`]) that the
//! device-derived corrected account must agree with, bucket by bucket,
//! within the coverage bound.

use crate::smi::{LogValue, QueryField, SmiLog};
use crate::units;

/// The rail the reconciliation pass (and normalisation) consumes.
pub const GPU_BOARD_RAIL: &str = "GPU Board Power";

/// Device name given to a replayed board rail. Deliberately **not** a
/// catalogue GPU: a host rail has no part-time sensor to identify, so
/// it must surface as an unrecognised device (excluded from the
/// identification accuracy metric) rather than masquerade as a GPU.
pub const BOARD_DEVICE_NAME: &str = "IPMI GPU Board (host rail)";

/// One polled row: time + one reading per rail.
#[derive(Debug, Clone, PartialEq)]
pub struct IpmiRow {
    /// Poll time, seconds since the dump started.
    pub t_s: f64,
    /// Watts per rail, parallel to [`IpmiLog::rails`]; `None` is `N/A`.
    pub watts: Vec<Option<u64>>,
}

/// A parsed IPMI sensor dump.
#[derive(Debug, Clone, PartialEq)]
pub struct IpmiLog {
    /// Rail names, in header order (everything after `time_s`).
    pub rails: Vec<String>,
    /// Poll rows, in file order.
    pub rows: Vec<IpmiRow>,
}

/// Parse an IPMI sensor dump. Total: malformed input yields a
/// line-numbered `Err`. CRLF endings and blank lines are tolerated; the
/// header must lead with `time_s` and name at least one rail.
pub fn parse_ipmi(text: &str) -> Result<IpmiLog, String> {
    let mut rails: Option<Vec<String>> = None;
    let mut rows = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        let Some(rails) = &rails else {
            if cells.first() != Some(&"time_s") || cells.len() < 2 {
                return Err(format!(
                    "line {}: expected header 'time_s,<rail>,...', got '{line}'",
                    ln + 1
                ));
            }
            if cells[1..].iter().any(|c| c.is_empty()) {
                return Err(format!("line {}: empty rail name in header", ln + 1));
            }
            rails = Some(cells[1..].iter().map(|c| c.to_string()).collect());
            continue;
        };
        if cells.len() != rails.len() + 1 {
            return Err(format!(
                "line {}: expected {} columns, got {}",
                ln + 1,
                rails.len() + 1,
                cells.len()
            ));
        }
        let t_s: f64 = cells[0]
            .parse()
            .map_err(|_| format!("line {}: bad time_s '{}'", ln + 1, cells[0]))?;
        if !t_s.is_finite() || t_s < 0.0 {
            return Err(format!("line {}: bad time_s '{}'", ln + 1, cells[0]));
        }
        let watts = cells[1..]
            .iter()
            .map(|c| {
                if *c == "N/A" {
                    Ok(None)
                } else {
                    c.parse::<u64>().map(Some).map_err(|_| {
                        format!("line {}: bad watts '{c}' (integer or N/A)", ln + 1)
                    })
                }
            })
            .collect::<Result<Vec<_>, String>>()?;
        rows.push(IpmiRow { t_s, watts });
    }
    let rails = rails.ok_or("dump is empty (no header row)")?;
    Ok(IpmiLog { rails, rows })
}

impl IpmiLog {
    /// Re-emit in the canonical dump form; inverse of [`parse_ipmi`] on
    /// canonical text (byte round-trip pinned by tests).
    pub fn format(&self) -> String {
        let mut out = String::from("time_s,");
        out.push_str(&self.rails.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&format!("{:.3}", r.t_s));
            for w in &r.watts {
                out.push(',');
                match w {
                    Some(w) => out.push_str(&w.to_string()),
                    None => out.push_str("N/A"),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Column index of `rail`, if present.
    pub fn rail_index(&self, rail: &str) -> Option<usize> {
        self.rails.iter().position(|r| r == rail)
    }

    /// `(seconds, watts)` series for one rail; `N/A` polls are skipped.
    /// Errors when the dump has no such rail.
    pub fn rail_series(&self, rail: &str) -> Result<Vec<(f64, f64)>, String> {
        let c = self
            .rail_index(rail)
            .ok_or_else(|| format!("dump has no '{rail}' rail (rails: {})", self.rails.join(", ")))?;
        Ok(self
            .rows
            .iter()
            .filter_map(|r| r.watts[c].map(|w| (r.t_s, w as f64)))
            .collect())
    }

    /// Normalise the [`GPU_BOARD_RAIL`] into the canonical recorded-log
    /// form, named [`BOARD_DEVICE_NAME`] so identification treats it as
    /// an unrecognised (host-side) device. Errors when the dump has no
    /// board rail.
    pub fn to_smi_log(&self) -> Result<SmiLog, String> {
        let c = self.rail_index(GPU_BOARD_RAIL).ok_or_else(|| {
            format!("dump has no '{GPU_BOARD_RAIL}' rail (rails: {})", self.rails.join(", "))
        })?;
        let fields = vec![QueryField::Timestamp, QueryField::Name, QueryField::PowerDraw];
        let rows = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    LogValue::Seconds(r.t_s),
                    LogValue::Text(BOARD_DEVICE_NAME.to_string()),
                    LogValue::Watts(r.watts[c].map(|w| w as f64)),
                ]
            })
            .collect();
        Ok(SmiLog { fields, rows })
    }

    /// Writer: render a `(seconds, watts)` series as the board rail of a
    /// five-rail dump (the other rails carry plausible constant host
    /// draw). Quantises to the format's native **integer watts**.
    pub fn from_gpu_board_series(points: &[(f64, f64)]) -> IpmiLog {
        let rails = ["Sys Power", "CPU Power", "Mem Power", GPU_BOARD_RAIL, "Riser 1 Power"];
        let rows = points
            .iter()
            .map(|&(t, w)| {
                let board = w.round().max(0.0) as u64;
                IpmiRow {
                    t_s: units::ms_to_s(units::s_to_ms(t).round()),
                    watts: vec![
                        Some(board + 320), // Sys ≈ board + CPU + Mem + riser + slack
                        Some(180),
                        Some(96),
                        Some(board),
                        Some(12),
                    ],
                }
            })
            .collect();
        IpmiLog { rails: rails.iter().map(|r| r.to_string()).collect(), rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CANONICAL: &str = "time_s,Sys Power,CPU Power,Mem Power,GPU Board Power,Riser 1 Power\n\
                             0.000,620,184,96,250,12\n\
                             1.000,933,210,101,N/A,13\n\
                             2.000,1010,214,102,610,13\n";

    #[test]
    fn canonical_text_round_trips_byte_for_byte() {
        let log = parse_ipmi(CANONICAL).unwrap();
        assert_eq!(log.rails.len(), 5);
        assert_eq!(log.rails[3], GPU_BOARD_RAIL);
        assert_eq!(log.rows.len(), 3);
        assert_eq!(log.rows[1].watts[3], None);
        assert_eq!(log.format(), CANONICAL);
    }

    #[test]
    fn rail_series_skips_na_polls() {
        let log = parse_ipmi(CANONICAL).unwrap();
        assert_eq!(log.rail_series(GPU_BOARD_RAIL).unwrap(), vec![(0.0, 250.0), (2.0, 610.0)]);
        assert_eq!(log.rail_series("CPU Power").unwrap().len(), 3);
        assert!(log.rail_series("PSU 7").is_err());
    }

    #[test]
    fn board_rail_normalises_as_an_unrecognised_host_device() {
        let smi = parse_ipmi(CANONICAL).unwrap().to_smi_log().unwrap();
        assert_eq!(smi.model_name(), Some(BOARD_DEVICE_NAME));
        assert!(crate::sim::profile::find_model(BOARD_DEVICE_NAME).is_none(),
            "the host rail must NOT resolve to a catalogue GPU");
        let series = smi.power_series(&QueryField::PowerDraw).unwrap();
        assert_eq!(series, vec![(0.0, 250.0), (2.0, 610.0)]);
        let text = smi.format();
        assert_eq!(crate::smi::parse_log(&text).unwrap().format(), text);
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = parse_ipmi("time_s,GPU Board Power\n0.0,watts\n").unwrap_err();
        assert!(e.contains("line 2") && e.contains("watts"), "{e}");
        let e = parse_ipmi("time_s,GPU Board Power\n0.0,1,2\n").unwrap_err();
        assert!(e.contains("line 2") && e.contains("columns"), "{e}");
        let e = parse_ipmi("time_s,GPU Board Power\nlater,1\n").unwrap_err();
        assert!(e.contains("line 2") && e.contains("time_s"), "{e}");
        let e = parse_ipmi("wrong,header\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        let e = parse_ipmi("time_s\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        assert!(parse_ipmi("").is_err());
        // a dump without the board rail parses, but cannot normalise
        let log = parse_ipmi("time_s,Sys Power\n0.000,620\n").unwrap();
        assert!(log.to_smi_log().unwrap_err().contains(GPU_BOARD_RAIL));
    }

    #[test]
    fn crlf_is_tolerated() {
        let text = CANONICAL.replace('\n', "\r\n");
        assert_eq!(parse_ipmi(&text).unwrap(), parse_ipmi(CANONICAL).unwrap());
    }

    #[test]
    fn writer_round_trips_and_sys_rail_dominates_board() {
        let log = IpmiLog::from_gpu_board_series(&[(0.0, 249.6), (0.5, 610.2)]);
        assert_eq!(log.rows[0].watts[3], Some(250));
        assert_eq!(log.rows[1].watts[3], Some(610));
        for r in &log.rows {
            assert!(r.watts[0] > r.watts[3], "Sys Power includes the board and more");
        }
        let text = log.format();
        assert_eq!(parse_ipmi(&text).unwrap(), log);
    }
}
