//! amdsmi profiler CSV: integer-watt socket power with `N/A` dropouts.
//!
//! The format AMD-side LLM-inference power profilers dump from
//! `amdsmi_get_power_info` (`current_socket_power`, integer watts or the
//! literal string `N/A`), `amdsmi_get_gpu_activity` (`gfx_activity`, %),
//! and `amdsmi_get_gpu_vram_usage` (MiB):
//!
//! ```text
//! timestamp,device,socket_power_w,gfx_activity_pct,vram_used_mb
//! 0.000,Instinct MI210,41,2,512
//! 0.100,Instinct MI210,N/A,97,16384
//! ```
//!
//! Socket power is a **boxcar average over a much longer window than the
//! telemetry readout cadence** (the CDNA entries in
//! [`crate::sim::profile`] encode this class), which is exactly the
//! paper's mechanism on different silicon: naive integration of these
//! rows mis-states energy until the window is identified and corrected.

use crate::smi::{LogValue, QueryField, SmiLog};

/// One sampled amdsmi row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AmdsmiRow {
    /// Sample time, milliseconds since the log started (stored in ms so
    /// the row is `Eq`/exact; rendered as seconds with 3 decimals).
    pub time_ms: u64,
    /// Socket power, integer watts; `None` is amdsmi's literal `N/A`.
    pub socket_power_w: Option<u64>,
    /// `gfx_activity` percent; `None` is `N/A`.
    pub gfx_activity_pct: Option<u64>,
    /// VRAM used, MiB; `None` is `N/A`.
    pub vram_used_mb: Option<u64>,
}

/// A parsed amdsmi profiler CSV.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AmdsmiLog {
    /// Device name (constant across rows; mismatching rows are an error).
    pub device: String,
    /// Sample rows, in file order.
    pub rows: Vec<AmdsmiRow>,
}

const HEADER: [&str; 5] = ["timestamp", "device", "socket_power_w", "gfx_activity_pct", "vram_used_mb"];

fn parse_na_u64(cell: &str, ln: usize, what: &str) -> Result<Option<u64>, String> {
    if cell == "N/A" {
        return Ok(None);
    }
    cell.parse::<u64>()
        .map(Some)
        .map_err(|_| format!("line {}: bad {what} '{cell}' (integer or N/A)", ln + 1))
}

/// Parse an amdsmi profiler CSV. Total: malformed input yields a
/// line-numbered `Err`, never a panic. CRLF endings and blank lines are
/// tolerated; every row must name the same device.
pub fn parse_amdsmi(text: &str) -> Result<AmdsmiLog, String> {
    let mut saw_header = false;
    let mut device: Option<String> = None;
    let mut rows = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).collect();
        if !saw_header {
            if cells != HEADER {
                return Err(format!(
                    "line {}: expected header '{}', got '{line}'",
                    ln + 1,
                    HEADER.join(",")
                ));
            }
            saw_header = true;
            continue;
        }
        if cells.len() != HEADER.len() {
            return Err(format!(
                "line {}: expected {} columns, got {}",
                ln + 1,
                HEADER.len(),
                cells.len()
            ));
        }
        let t: f64 = cells[0]
            .parse()
            .map_err(|_| format!("line {}: bad timestamp '{}'", ln + 1, cells[0]))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("line {}: bad timestamp '{}'", ln + 1, cells[0]));
        }
        match &device {
            None => device = Some(cells[1].to_string()),
            Some(d) if d != cells[1] => {
                return Err(format!(
                    "line {}: device '{}' differs from first row's '{d}'",
                    ln + 1,
                    cells[1]
                ))
            }
            Some(_) => {}
        }
        rows.push(AmdsmiRow {
            time_ms: crate::units::s_to_ms(t).round() as u64,
            socket_power_w: parse_na_u64(cells[2], ln, "socket_power_w")?,
            gfx_activity_pct: parse_na_u64(cells[3], ln, "gfx_activity_pct")?,
            vram_used_mb: parse_na_u64(cells[4], ln, "vram_used_mb")?,
        });
    }
    if !saw_header {
        return Err("log is empty (no header row)".into());
    }
    // a device name is only known once a data row exists
    let device = device.ok_or("log has a header but no data rows")?;
    Ok(AmdsmiLog { device, rows })
}

impl AmdsmiLog {
    /// Re-emit in the canonical amdsmi CSV form; inverse of
    /// [`parse_amdsmi`] on canonical text (byte round-trip pinned).
    pub fn format(&self) -> String {
        let na = |v: Option<u64>| v.map_or_else(|| "N/A".into(), |x| x.to_string());
        let mut out = HEADER.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&format!(
                "{:.3},{},{},{},{}\n",
                crate::units::ms_to_s(r.time_ms as f64),
                self.device,
                na(r.socket_power_w),
                na(r.gfx_activity_pct),
                na(r.vram_used_mb),
            ));
        }
        out
    }

    /// Normalise into the canonical recorded-log form (socket power as
    /// the `power.draw` column, `N/A` dropouts preserved).
    pub fn to_smi_log(&self) -> SmiLog {
        let fields = vec![QueryField::Timestamp, QueryField::Name, QueryField::PowerDraw];
        let rows = self
            .rows
            .iter()
            .map(|r| {
                vec![
                    LogValue::Seconds(crate::units::ms_to_s(r.time_ms as f64)),
                    LogValue::Text(self.device.clone()),
                    LogValue::Watts(r.socket_power_w.map(|w| w as f64)),
                ]
            })
            .collect();
        SmiLog { fields, rows }
    }

    /// Writer: render a `(seconds, watts)` series as an amdsmi CSV —
    /// quantising to the format's native **integer watts** (the coarsest
    /// quantisation of the four schemas; the differential test's naive
    /// tolerance accounts for up to 0.5 W per sample).
    pub fn from_series(device: &str, points: &[(f64, f64)]) -> AmdsmiLog {
        let rows = points
            .iter()
            .map(|&(t, w)| AmdsmiRow {
                time_ms: crate::units::s_to_ms(t).round().max(0.0) as u64,
                socket_power_w: Some(w.round().max(0.0) as u64),
                gfx_activity_pct: None,
                vram_used_mb: None,
            })
            .collect();
        AmdsmiLog { device: device.to_string(), rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CANONICAL: &str = "timestamp,device,socket_power_w,gfx_activity_pct,vram_used_mb\n\
                             0.000,Instinct MI210,41,2,512\n\
                             0.100,Instinct MI210,N/A,97,16384\n\
                             0.200,Instinct MI210,290,99,16384\n";

    #[test]
    fn canonical_text_round_trips_byte_for_byte() {
        let log = parse_amdsmi(CANONICAL).unwrap();
        assert_eq!(log.device, "Instinct MI210");
        assert_eq!(log.rows.len(), 3);
        assert_eq!(log.rows[0].socket_power_w, Some(41));
        assert_eq!(log.rows[1].socket_power_w, None);
        assert_eq!(log.rows[2].vram_used_mb, Some(16_384));
        assert_eq!(log.format(), CANONICAL);
    }

    #[test]
    fn normalisation_maps_socket_power_to_power_draw() {
        let smi = parse_amdsmi(CANONICAL).unwrap().to_smi_log();
        assert_eq!(smi.model_name(), Some("Instinct MI210"));
        assert_eq!(smi.first_power_field(), Some(QueryField::PowerDraw));
        let series = smi.power_series(&QueryField::PowerDraw).unwrap();
        assert_eq!(series, vec![(0.0, 41.0), (0.2, 290.0)]);
        let text = smi.format();
        assert_eq!(crate::smi::parse_log(&text).unwrap().format(), text);
    }

    #[test]
    fn errors_are_line_numbered() {
        let hdr = "timestamp,device,socket_power_w,gfx_activity_pct,vram_used_mb\n";
        let e = parse_amdsmi(&format!("{hdr}0.0,MI210,watts,1,2\n")).unwrap_err();
        assert!(e.contains("line 2") && e.contains("socket_power_w"), "{e}");
        let e = parse_amdsmi(&format!("{hdr}0.0,MI210,1,2\n")).unwrap_err();
        assert!(e.contains("line 2") && e.contains("columns"), "{e}");
        let e = parse_amdsmi(&format!("{hdr}nan,MI210,1,2,3\n")).unwrap_err();
        assert!(e.contains("line 2") && e.contains("timestamp"), "{e}");
        let e = parse_amdsmi(&format!("{hdr}0.0,MI210,1,2,3\n0.1,MI250X,1,2,3\n")).unwrap_err();
        assert!(e.contains("line 3") && e.contains("differs"), "{e}");
        let e = parse_amdsmi("time,power\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        assert!(parse_amdsmi("").is_err());
        assert!(parse_amdsmi(hdr).is_err(), "header but no rows");
    }

    #[test]
    fn crlf_is_tolerated() {
        let text = CANONICAL.replace('\n', "\r\n");
        assert_eq!(parse_amdsmi(&text).unwrap(), parse_amdsmi(CANONICAL).unwrap());
    }

    #[test]
    fn writer_round_trips_and_quantises_to_integer_watts() {
        let log = AmdsmiLog::from_series("Instinct MI210", &[(0.0, 41.4), (0.1, 289.6)]);
        assert_eq!(log.rows[0].socket_power_w, Some(41));
        assert_eq!(log.rows[1].socket_power_w, Some(290));
        let text = log.format();
        assert_eq!(parse_amdsmi(&text).unwrap(), log);
    }
}
