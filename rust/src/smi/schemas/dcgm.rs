//! DCGM/Prometheus text exposition scrapes: timestamped
//! `DCGM_FI_DEV_POWER_USAGE` samples.
//!
//! The format a Prometheus server (or `curl` against dcgm-exporter)
//! accumulates when it scrapes the DCGM power gauge — `# HELP`/`# TYPE`
//! preamble, then one sample line per scrape with float watts and a
//! **millisecond** epoch timestamp:
//!
//! ```text
//! # HELP DCGM_FI_DEV_POWER_USAGE Power draw (in W).
//! # TYPE DCGM_FI_DEV_POWER_USAGE gauge
//! DCGM_FI_DEV_POWER_USAGE{gpu="0",modelName="A100 PCIe-40G"} 61.15 1700000000000
//! DCGM_FI_DEV_POWER_USAGE{gpu="0",modelName="A100 PCIe-40G"} 63.79 1700000000100
//! ```
//!
//! Sample lines for *other* metrics are skipped (a real scrape carries
//! dozens), the label set must stay constant across samples, and epoch
//! timestamps are normalised to relative seconds at the first sample in
//! [`DcgmScrape::to_smi_log`] — mirroring how the canonical parser
//! normalises nvidia-smi wall-clock stamps.

use crate::smi::{LogValue, QueryField, SmiLog};
use crate::units;

/// The one metric this reproduction consumes from a scrape.
pub const POWER_METRIC: &str = "DCGM_FI_DEV_POWER_USAGE";

/// A parsed scrape: the power gauge's samples for one GPU.
#[derive(Debug, Clone, PartialEq)]
pub struct DcgmScrape {
    /// `gpu` label value (exporter device index).
    pub gpu: String,
    /// `modelName` label value — what replay scores the device against.
    pub model_name: String,
    /// `(epoch ms, watts)` samples, in file order.
    pub rows: Vec<(u64, f64)>,
}

/// Split `gpu="0",modelName="A100"` into label pairs; `None` on any
/// malformed pair (missing quotes/equals).
fn parse_labels(body: &str) -> Option<Vec<(String, String)>> {
    let mut out = Vec::new();
    if body.trim().is_empty() {
        return Some(out);
    }
    for pair in body.split(',') {
        let (k, v) = pair.split_once('=')?;
        let v = v.trim().strip_prefix('"')?.strip_suffix('"')?;
        out.push((k.trim().to_string(), v.to_string()));
    }
    Some(out)
}

fn label<'a>(labels: &'a [(String, String)], key: &str) -> Option<&'a str> {
    labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// Parse a Prometheus exposition scrape, extracting the
/// [`POWER_METRIC`] samples. Total: malformed sample lines of the power
/// metric are line-numbered errors; other metrics and comments are
/// skipped; label sets must not change mid-scrape.
pub fn parse_dcgm(text: &str) -> Result<DcgmScrape, String> {
    let mut scrape: Option<DcgmScrape> = None;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if !line.starts_with(POWER_METRIC) {
            continue; // a real scrape carries many metrics; only power matters here
        }
        let rest = &line[POWER_METRIC.len()..];
        let (labels, rest) = if let Some(r) = rest.strip_prefix('{') {
            let (body, tail) = r
                .split_once('}')
                .ok_or_else(|| format!("line {}: unterminated label set", ln + 1))?;
            let labels = parse_labels(body)
                .ok_or_else(|| format!("line {}: malformed label set '{{{body}}}'", ln + 1))?;
            (labels, tail)
        } else {
            (Vec::new(), rest)
        };
        let mut parts = rest.split_whitespace();
        let value: f64 = parts
            .next()
            .ok_or_else(|| format!("line {}: sample has no value", ln + 1))?
            .parse()
            .map_err(|_| format!("line {}: bad sample value", ln + 1))?;
        if !value.is_finite() {
            return Err(format!("line {}: non-finite sample value", ln + 1));
        }
        let stamp: u64 = parts
            .next()
            .ok_or_else(|| format!("line {}: sample has no timestamp (replay needs one)", ln + 1))?
            .parse()
            .map_err(|_| format!("line {}: bad timestamp (epoch milliseconds)", ln + 1))?;
        if parts.next().is_some() {
            return Err(format!("line {}: trailing tokens after timestamp", ln + 1));
        }
        let gpu = label(&labels, "gpu").unwrap_or("0").to_string();
        let model_name = label(&labels, "modelName")
            .ok_or_else(|| format!("line {}: sample lacks a modelName label", ln + 1))?
            .to_string();
        match &mut scrape {
            None => scrape = Some(DcgmScrape { gpu, model_name, rows: vec![(stamp, value)] }),
            Some(s) => {
                if s.gpu != gpu || s.model_name != model_name {
                    return Err(format!(
                        "line {}: labels (gpu={gpu}, modelName={model_name}) differ from first sample",
                        ln + 1
                    ));
                }
                s.rows.push((stamp, value));
            }
        }
    }
    scrape.ok_or_else(|| format!("scrape has no {POWER_METRIC} samples"))
}

impl DcgmScrape {
    /// Re-emit in canonical exposition form; inverse of [`parse_dcgm`]
    /// on canonical text (byte round-trip pinned by tests).
    pub fn format(&self) -> String {
        let mut out = format!("# HELP {POWER_METRIC} Power draw (in W).\n# TYPE {POWER_METRIC} gauge\n");
        for &(ms, w) in &self.rows {
            out.push_str(&format!(
                "{POWER_METRIC}{{gpu=\"{}\",modelName=\"{}\"}} {w:.2} {ms}\n",
                self.gpu, self.model_name
            ));
        }
        out
    }

    /// Normalise into the canonical recorded-log form: epoch
    /// milliseconds → relative seconds at the first sample.
    pub fn to_smi_log(&self) -> SmiLog {
        let fields = vec![QueryField::Timestamp, QueryField::Name, QueryField::PowerDraw];
        let t0 = self.rows.first().map_or(0, |&(ms, _)| ms);
        let rows = self
            .rows
            .iter()
            .map(|&(ms, w)| {
                vec![
                    LogValue::Seconds(units::ms_to_s(ms.saturating_sub(t0) as f64)),
                    LogValue::Text(self.model_name.clone()),
                    LogValue::Watts(Some(w)),
                ]
            })
            .collect();
        SmiLog { fields, rows }
    }

    /// Writer: render a `(seconds, watts)` series as a scrape anchored
    /// at epoch `t0_ms`. Quantises to the format's native resolution:
    /// millisecond timestamps and the exporter's 2-decimal watts.
    pub fn from_series(model_name: &str, t0_ms: u64, points: &[(f64, f64)]) -> DcgmScrape {
        let rows = points
            .iter()
            .map(|&(t, w)| (t0_ms + units::s_to_ms(t).round().max(0.0) as u64, w))
            .collect();
        DcgmScrape { gpu: "0".into(), model_name: model_name.to_string(), rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CANONICAL: &str = "# HELP DCGM_FI_DEV_POWER_USAGE Power draw (in W).\n\
                             # TYPE DCGM_FI_DEV_POWER_USAGE gauge\n\
                             DCGM_FI_DEV_POWER_USAGE{gpu=\"0\",modelName=\"A100 PCIe-40G\"} 61.15 1700000000000\n\
                             DCGM_FI_DEV_POWER_USAGE{gpu=\"0\",modelName=\"A100 PCIe-40G\"} 63.79 1700000000100\n";

    #[test]
    fn canonical_text_round_trips_byte_for_byte() {
        let s = parse_dcgm(CANONICAL).unwrap();
        assert_eq!(s.gpu, "0");
        assert_eq!(s.model_name, "A100 PCIe-40G");
        assert_eq!(s.rows, vec![(1_700_000_000_000, 61.15), (1_700_000_000_100, 63.79)]);
        assert_eq!(s.format(), CANONICAL);
    }

    #[test]
    fn epoch_timestamps_normalise_to_relative_seconds() {
        let smi = parse_dcgm(CANONICAL).unwrap().to_smi_log();
        assert_eq!(smi.model_name(), Some("A100 PCIe-40G"));
        let series = smi.power_series(&QueryField::PowerDraw).unwrap();
        assert_eq!(series, vec![(0.0, 61.15), (0.1, 63.79)]);
        let text = smi.format();
        assert_eq!(crate::smi::parse_log(&text).unwrap().format(), text);
    }

    #[test]
    fn unrelated_metrics_and_comments_are_skipped() {
        let text = format!(
            "# HELP DCGM_FI_DEV_GPU_TEMP temp\n\
             DCGM_FI_DEV_GPU_TEMP{{gpu=\"0\"}} 55 1700000000000\n\
             {CANONICAL}\
             DCGM_FI_DEV_SM_CLOCK{{gpu=\"0\"}} 1410 1700000000100\n"
        );
        assert_eq!(parse_dcgm(&text).unwrap(), parse_dcgm(CANONICAL).unwrap());
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = parse_dcgm("DCGM_FI_DEV_POWER_USAGE{gpu=\"0\",modelName=\"X\"} 61.15\n").unwrap_err();
        assert!(e.contains("line 1") && e.contains("timestamp"), "{e}");
        let e = parse_dcgm("DCGM_FI_DEV_POWER_USAGE{gpu=\"0\"} 61.15 1700000000000\n").unwrap_err();
        assert!(e.contains("line 1") && e.contains("modelName"), "{e}");
        let e = parse_dcgm("DCGM_FI_DEV_POWER_USAGE{gpu=0} 61.15 1\n").unwrap_err();
        assert!(e.contains("line 1") && e.contains("label"), "{e}");
        let e = parse_dcgm("DCGM_FI_DEV_POWER_USAGE{gpu=\"0\",modelName=\"X\"} watts 1\n").unwrap_err();
        assert!(e.contains("line 1") && e.contains("value"), "{e}");
        let e = parse_dcgm(
            "DCGM_FI_DEV_POWER_USAGE{gpu=\"0\",modelName=\"X\"} 1.0 1\n\
             DCGM_FI_DEV_POWER_USAGE{gpu=\"1\",modelName=\"X\"} 2.0 2\n",
        )
        .unwrap_err();
        assert!(e.contains("line 2") && e.contains("differ"), "{e}");
        assert!(parse_dcgm("").is_err());
        assert!(parse_dcgm("# only comments\n").is_err());
    }

    #[test]
    fn writer_round_trips() {
        let s = DcgmScrape::from_series("A100 PCIe-40G", 1_700_000_000_000, &[(0.0, 61.154), (0.1, 63.786)]);
        let text = s.format();
        let back = parse_dcgm(&text).unwrap();
        // values survive at the exporter's 2-decimal resolution
        assert_eq!(back.rows[0].0, 1_700_000_000_000);
        assert!((back.rows[0].1 - 61.15).abs() < 1e-12);
        assert!((back.rows[1].1 - 63.79).abs() < 1e-12);
        assert_eq!(back.format(), text);
    }
}
