//! The paper's §5.1 good-practice energy measurement procedure:
//!
//! 1. Execute the target program for ≥32 consecutive iterations or until a
//!    minimum runtime of 5 s; if data loss occurs (averaging window shorter
//!    than the update period), insert 8 controlled delays evenly spaced
//!    within the repetitions.
//! 2. Perform four separate trials with a randomised delay between each.
//! 3. Post-process: discard repetitions during rise time, and shift the
//!    data to synchronise with GPU activity (boxcar latency).
//! 4. Optionally apply the steady-state gradient/offset correction (§5.3).

use super::energy::{mean_power, mean_power_points, shift_earlier, shift_earlier_into};
use super::{
    capture_streaming, pmd_window_mean, MeasureScratch, MeasurementRig, PowerCorrection,
    RepeatableLoad, SensorCharacterization,
};
use crate::estimator::stats::{mean, pct_error, std_dev};
use crate::rng::Rng;
use crate::smi::poll_readings;

/// Configuration of the good-practice procedure (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct GoodPracticeConfig {
    /// Minimum consecutive iterations (paper: 32).
    pub min_reps: usize,
    /// Minimum total runtime, seconds (paper: 5).
    pub min_runtime_s: f64,
    /// Controlled delays to insert when the window undersamples (paper: 8).
    pub shifts: usize,
    /// Independent trials with randomised inter-trial delay (paper: 4).
    pub trials: usize,
    /// nvidia-smi polling cadence, seconds.
    pub poll_period_s: f64,
    /// Optional steady-state power correction.
    pub correction: Option<PowerCorrection>,
}

impl Default for GoodPracticeConfig {
    fn default() -> Self {
        GoodPracticeConfig {
            min_reps: 32,
            min_runtime_s: 5.0,
            shifts: 8,
            trials: 4,
            poll_period_s: 0.02,
            correction: None,
        }
    }
}

/// Aggregated outcome across trials.
#[derive(Debug, Clone)]
pub struct GoodPracticeResult {
    /// Per-trial percentage error vs the PMD.
    pub trial_pct_errors: Vec<f64>,
    /// Mean percentage error.
    pub mean_pct_error: f64,
    /// Std-dev of the per-trial errors.
    pub std_pct_error: f64,
    /// Mean measured power over the analysis window, watts.
    pub mean_power_w: f64,
    /// Energy for one iteration of the program, joules.
    pub energy_per_iteration_j: f64,
    /// Iterations actually used per trial.
    pub reps: usize,
    /// Whether phase shifts were applied.
    pub shifted: bool,
}

/// Run the full §5.1 procedure for `load` on `rig`.
///
/// `sensor` carries only the knowledge the micro-benchmarks provide
/// (update period, window, rise time) — the procedure never touches the
/// simulator's hidden profile.
pub fn measure_good_practice<L: RepeatableLoad>(
    rig: &MeasurementRig,
    load: &L,
    sensor: &SensorCharacterization,
    cfg: &GoodPracticeConfig,
) -> GoodPracticeResult {
    // Step 1: repetitions to cover both floors.
    let iter_s = load.iteration_s();
    let reps = cfg.min_reps.max((cfg.min_runtime_s / iter_s).ceil() as usize);
    let (reps_per_shift, shift_s, shifted) = if sensor.has_data_loss() && cfg.shifts > 0 {
        ((reps / cfg.shifts).max(1), sensor.window_s, true)
    } else {
        (0, 0.0, false)
    };

    let mut rng = Rng::new(rig.seed ^ 0x60D0);
    let mut trial_errors = Vec::with_capacity(cfg.trials);
    let mut powers = Vec::with_capacity(cfg.trials);

    for trial in 0..cfg.trials {
        // Step 2: randomised alignment delay between trials.
        let t_start = 0.5 + rng.uniform();
        let activity = load.build(t_start, reps, reps_per_shift, shift_s);
        let t_busy_end = activity.t_end();
        let boot_seed = rig.seed ^ (trial as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        // synthesize/poll past the end so the shifted series still covers
        // the analysis window even for a 1 s boxcar (Case 2)
        let t_tail = sensor.window_s + 2.0 * sensor.update_s;
        let cap = rig.capture(&activity, 0.0, t_busy_end + t_tail + 0.3, boot_seed);

        let log = cap.smi.poll(
            rig.field,
            cfg.poll_period_s,
            t_start - 2.0 * sensor.window_s.max(sensor.update_s),
            t_busy_end + t_tail,
        );

        // Step 3a: shift readings earlier by the boxcar group delay (the
        // reading at t is the mean over [t-w, t], i.e. activity centred
        // w/2 prior).
        let mut series = shift_earlier(&log.series, sensor.window_s / 2.0);
        // Step 3b: optional steady-state correction.
        if let Some(c) = &cfg.correction {
            series = c.correct_series(&series);
        }
        // Step 3c: discard whole repetitions covering rise time + window ramp.
        let settle_s = sensor.rise_s + sensor.window_s;
        let discard_iters = (settle_s / iter_s).ceil();
        let t_analysis_start = t_start + discard_iters * iter_s;

        let p_smi = mean_power(&series, t_analysis_start, t_busy_end);
        let p_truth = {
            let prefix = cap.pmd_trace.prefix_sums();
            pmd_window_mean(&prefix, cap.pmd_trace.view(), t_analysis_start, t_busy_end)
        };
        trial_errors.push(pct_error(p_smi, p_truth));
        powers.push(p_smi);
    }

    let mean_power_w = mean(&powers);
    GoodPracticeResult {
        mean_pct_error: mean(&trial_errors),
        std_pct_error: std_dev(&trial_errors),
        trial_pct_errors: trial_errors,
        mean_power_w,
        energy_per_iteration_j: mean_power_w * iter_s,
        reps,
        shifted,
    }
}

/// Aggregate view of a streaming good-practice run; the per-trial errors
/// stay in the scratch arena (`scratch.trial_errors`) so the fleet hot
/// path allocates nothing per node.
#[derive(Debug, Clone, Copy)]
pub(crate) struct GoodPracticeSummary {
    pub mean_pct_error: f64,
    pub std_pct_error: f64,
    pub mean_power_w: f64,
    pub reps: usize,
    pub shifted: bool,
}

/// The §5.1 procedure on the streaming pipeline: identical seeds, trial
/// structure and arithmetic to [`measure_good_practice`] (pinned
/// bit-for-bit by tests), but every capture/poll/shift/prefix buffer comes
/// from the reused per-worker [`MeasureScratch`].
pub(crate) fn good_practice_core<L: RepeatableLoad>(
    rig: &MeasurementRig,
    load: &L,
    sensor: &SensorCharacterization,
    cfg: &GoodPracticeConfig,
    scratch: &mut MeasureScratch,
) -> GoodPracticeSummary {
    // Step 1: repetitions to cover both floors.
    let iter_s = load.iteration_s();
    let reps = cfg.min_reps.max((cfg.min_runtime_s / iter_s).ceil() as usize);
    let (reps_per_shift, shift_s, shifted) = if sensor.has_data_loss() && cfg.shifts > 0 {
        ((reps / cfg.shifts).max(1), sensor.window_s, true)
    } else {
        (0, 0.0, false)
    };

    let mut rng = Rng::new(rig.seed ^ 0x60D0);
    scratch.trial_errors.clear();
    scratch.powers.clear();

    for trial in 0..cfg.trials {
        // Step 2: randomised alignment delay between trials.
        let t_start = 0.5 + rng.uniform();
        let mut activity = std::mem::take(&mut scratch.activity);
        load.build_into(t_start, reps, reps_per_shift, shift_s, &mut activity);
        let t_busy_end = activity.t_end();
        let boot_seed = rig.seed ^ (trial as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        // synthesize/poll past the end so the shifted series still covers
        // the analysis window even for a 1 s boxcar (Case 2)
        let t_tail = sensor.window_s + 2.0 * sensor.update_s;
        let meta =
            capture_streaming(rig, &activity, 0.0, t_busy_end + t_tail + 0.3, boot_seed, scratch);
        scratch.activity = activity;

        scratch.points.clear();
        poll_readings(
            &scratch.readings,
            Rng::new(boot_seed ^ 0x5149),
            cfg.poll_period_s,
            0.15,
            t_start - 2.0 * sensor.window_s.max(sensor.update_s),
            t_busy_end + t_tail,
            &mut scratch.points,
        );

        // Step 3a: shift readings earlier by the boxcar group delay (the
        // reading at t is the mean over [t-w, t], i.e. activity centred
        // w/2 prior).
        shift_earlier_into(&scratch.points, sensor.window_s / 2.0, &mut scratch.shifted);
        // Step 3b: optional steady-state correction (in place; same values
        // as PowerCorrection::correct_series).
        if let Some(c) = &cfg.correction {
            for p in &mut scratch.shifted {
                p.1 = c.correct(p.1);
            }
        }
        // Step 3c: discard whole repetitions covering rise time + window ramp.
        let settle_s = sensor.rise_s + sensor.window_s;
        let discard_iters = (settle_s / iter_s).ceil();
        let t_analysis_start = t_start + discard_iters * iter_s;

        let p_smi = mean_power_points(&scratch.shifted, t_analysis_start, t_busy_end);
        let p_truth = {
            scratch.pmd_prefix.clear();
            let mut acc = 0.0f64;
            for &s in &scratch.pmd {
                acc += s as f64;
                scratch.pmd_prefix.push(acc);
            }
            pmd_window_mean(
                &scratch.pmd_prefix,
                meta.pmd_view(&scratch.pmd),
                t_analysis_start,
                t_busy_end,
            )
        };
        scratch.trial_errors.push(pct_error(p_smi, p_truth));
        scratch.powers.push(p_smi);
    }

    GoodPracticeSummary {
        mean_pct_error: mean(&scratch.trial_errors),
        std_pct_error: std_dev(&scratch.trial_errors),
        mean_power_w: mean(&scratch.powers),
        reps,
        shifted,
    }
}

/// [`measure_good_practice`] on the streaming pipeline; bit-for-bit equal
/// results through the reused scratch arena.
pub fn measure_good_practice_streaming<L: RepeatableLoad>(
    rig: &MeasurementRig,
    load: &L,
    sensor: &SensorCharacterization,
    cfg: &GoodPracticeConfig,
    scratch: &mut MeasureScratch,
) -> GoodPracticeResult {
    let core = good_practice_core(rig, load, sensor, cfg, scratch);
    GoodPracticeResult {
        trial_pct_errors: scratch.trial_errors.clone(),
        mean_pct_error: core.mean_pct_error,
        std_pct_error: core.std_pct_error,
        mean_power_w: core.mean_power_w,
        energy_per_iteration_j: core.mean_power_w * load.iteration_s(),
        reps: core.reps,
        shifted: core.shifted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::BenchmarkLoad;
    use crate::sim::device::GpuDevice;
    use crate::sim::profile::{find_model, DriverEpoch, PowerField};

    fn rig(model: &str, driver: DriverEpoch, field: PowerField, seed: u64) -> MeasurementRig {
        let device = GpuDevice::new(find_model(model).unwrap(), 0, seed);
        MeasurementRig::new(device, driver, field, seed)
    }

    #[test]
    fn case1_error_converges_to_steady_state_margin() {
        // RTX 3090 instant (100/100): good practice error ≈ the card's
        // steady-state tolerance, with sub-percent spread (Fig. 15).
        let r = rig("RTX 3090", DriverEpoch::Post530, PowerField::Instant, 31);
        let sensor = SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 };
        let load = BenchmarkLoad::new(0.1, 1.0, 1);
        let out = measure_good_practice(&r, &load, &sensor, &GoodPracticeConfig::default());
        // error should be small and stable (tolerance is ±5%, plus the PMD's
        // 3.3 V rail gap of ~+2-3%)
        assert!(out.mean_pct_error.abs() < 10.0, "mean={:.2}%", out.mean_pct_error);
        assert!(out.std_pct_error < 2.0, "std={:.2}%", out.std_pct_error);
        assert!(!out.shifted);
        assert_eq!(out.reps, 50); // 5 s / 0.1 s
    }

    #[test]
    fn case3_shifts_are_applied_on_a100() {
        let r = rig("A100 PCIe-40G", DriverEpoch::Post530, PowerField::Instant, 33);
        let sensor = SensorCharacterization { update_s: 0.1, window_s: 0.025, rise_s: 0.1 };
        let load = BenchmarkLoad::new(0.1, 1.0, 1);
        let out = measure_good_practice(&r, &load, &sensor, &GoodPracticeConfig::default());
        assert!(out.shifted, "25/100 must trigger controlled delays");
        assert!(out.std_pct_error < 5.0, "shifts stabilise the error, std={:.2}", out.std_pct_error);
    }

    #[test]
    fn correction_reduces_error_to_near_zero() {
        // calibrate the correction from the card's actual tolerance and the
        // PMD's rail gap, then expect sub-percent residual (§5.3)
        let r = rig("RTX 3090", DriverEpoch::Post530, PowerField::Instant, 35);
        let sensor = SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 };
        let load = BenchmarkLoad::new(0.1, 1.0, 1);
        let plain = measure_good_practice(&r, &load, &sensor, &GoodPracticeConfig::default());
        // steady-state calibration: reported vs PMD at several levels
        let mut ref_w = Vec::new();
        let mut rep_w = Vec::new();
        for (i, util) in [0.2, 0.4, 0.6, 0.8, 1.0].iter().enumerate() {
            let act = crate::sim::ActivitySignal::burst(0.5, 3.0, *util);
            let cap = r.capture(&act, 0.0, 4.0, 1000 + i as u64);
            let p_pmd = cap.pmd_trace.window_mean(3.3, 1.0);
            let p_smi = cap.smi.query(PowerField::Instant, 3.3).unwrap();
            ref_w.push(p_pmd);
            rep_w.push(p_smi);
        }
        let corr = PowerCorrection::from_steady_state(&ref_w, &rep_w);
        let cfg = GoodPracticeConfig { correction: Some(corr), ..Default::default() };
        let fixed = measure_good_practice(&r, &load, &sensor, &cfg);
        assert!(
            fixed.mean_pct_error.abs() < plain.mean_pct_error.abs(),
            "correction must shrink error: {:.2}% -> {:.2}%",
            plain.mean_pct_error,
            fixed.mean_pct_error
        );
        assert!(fixed.mean_pct_error.abs() < 2.0, "residual {:.2}%", fixed.mean_pct_error);
    }

    #[test]
    fn streaming_matches_materialized_bit_for_bit() {
        use crate::bench::workloads::workload_by_name;
        let mut scratch = crate::measure::MeasureScratch::new();
        let cfg = GoodPracticeConfig { trials: 3, min_reps: 10, min_runtime_s: 1.0, ..Default::default() };
        for (model, driver, field, window_s) in [
            ("A100 PCIe-40G", DriverEpoch::Post530, PowerField::Instant, 0.025),
            ("RTX 3090", DriverEpoch::Post530, PowerField::Instant, 0.1),
            ("Tesla K40", DriverEpoch::Pre530, PowerField::Draw, 0.015),
        ] {
            let r = rig(model, driver, field, 77);
            let sensor = SensorCharacterization { update_s: 0.1, window_s, rise_s: 0.2 };
            for wl in ["cublas", "nvjpeg", "bert"] {
                let load = workload_by_name(wl).unwrap();
                let a = measure_good_practice(&r, load, &sensor, &cfg);
                let b = measure_good_practice_streaming(&r, load, &sensor, &cfg, &mut scratch);
                assert_eq!(a.trial_pct_errors.len(), b.trial_pct_errors.len());
                for (x, y) in a.trial_pct_errors.iter().zip(&b.trial_pct_errors) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{model}/{wl} trial error");
                }
                assert_eq!(a.mean_pct_error.to_bits(), b.mean_pct_error.to_bits(), "{model}/{wl}");
                assert_eq!(a.std_pct_error.to_bits(), b.std_pct_error.to_bits(), "{model}/{wl}");
                assert_eq!(a.mean_power_w.to_bits(), b.mean_power_w.to_bits(), "{model}/{wl}");
                assert_eq!(
                    a.energy_per_iteration_j.to_bits(),
                    b.energy_per_iteration_j.to_bits(),
                    "{model}/{wl}"
                );
                assert_eq!(a.reps, b.reps);
                assert_eq!(a.shifted, b.shifted);
            }
        }
    }

    #[test]
    fn streaming_correction_matches_materialized() {
        let r = rig("RTX 3090", DriverEpoch::Post530, PowerField::Instant, 91);
        let sensor = SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 };
        let load = BenchmarkLoad::new(0.1, 1.0, 1);
        let corr = PowerCorrection { gradient: 0.97, offset_w: 2.0, r2: 1.0 };
        let cfg = GoodPracticeConfig {
            trials: 2,
            min_reps: 10,
            min_runtime_s: 1.0,
            correction: Some(corr),
            ..Default::default()
        };
        let a = measure_good_practice(&r, &load, &sensor, &cfg);
        let mut scratch = crate::measure::MeasureScratch::new();
        let b = measure_good_practice_streaming(&r, &load, &sensor, &cfg, &mut scratch);
        assert_eq!(a.mean_pct_error.to_bits(), b.mean_pct_error.to_bits());
        assert_eq!(a.mean_power_w.to_bits(), b.mean_power_w.to_bits());
    }

    #[test]
    fn reps_respect_min_runtime() {
        let r = rig("RTX 3090", DriverEpoch::Post530, PowerField::Instant, 36);
        let sensor = SensorCharacterization { update_s: 0.1, window_s: 0.1, rise_s: 0.25 };
        // 25 ms iterations: 5 s floor -> 200 reps
        let load = BenchmarkLoad::new(0.025, 1.0, 1);
        let cfg = GoodPracticeConfig { trials: 1, ..Default::default() };
        let out = measure_good_practice(&r, &load, &sensor, &cfg);
        assert_eq!(out.reps, 200);
    }
}
