//! The naive measurement: what most surveyed papers do (§2.6) — run the
//! workload once, poll nvidia-smi, integrate over the kernel execution
//! window, take the number as ground truth.
//!
//! [`measure_naive`] is the materialised reference; [`measure_naive_streaming`]
//! runs the identical procedure through the chunked capture and a reused
//! [`MeasureScratch`], producing bit-for-bit the same result (pinned by
//! tests below) with O(chunk) allocation.

use super::energy::{mean_power, mean_power_points};
use super::{capture_streaming, MeasureScratch, MeasurementRig, RepeatableLoad};
use crate::estimator::stats::pct_error;
use crate::rng::Rng;
use crate::smi::poll_readings;

/// Outcome of one naive measurement.
#[derive(Debug, Clone, Copy)]
pub struct NaiveResult {
    /// Energy nvidia-smi implies for the program, joules.
    pub energy_j: f64,
    /// PMD ground-truth energy over the same window, joules.
    pub truth_j: f64,
    /// Percentage error vs the PMD.
    pub pct_error: f64,
    /// Mean reported power over the window, watts.
    pub mean_power_w: f64,
    /// Duration of the measured kernel-execution window, seconds (used by
    /// fleet reports to turn energies back into mean draws).
    pub window_s: f64,
}

/// Measure one run of `load` naively: single execution, power integrated
/// over exactly the kernel execution window, no corrections.
pub fn measure_naive<L: RepeatableLoad>(
    rig: &MeasurementRig,
    load: &L,
    poll_period_s: f64,
    run_seed: u64,
) -> NaiveResult {
    // one repetition, started at an arbitrary (uncontrolled) time
    let mut rng = Rng::new(rig.seed ^ run_seed);
    let t_start = 0.5 + rng.uniform();
    let activity = load.build(t_start, 1, 0, 0.0);
    let t_end = activity.t_end();
    let cap = rig.capture(&activity, 0.0, t_end + 0.5, rig.seed ^ run_seed ^ 0xB001);

    let log = cap.smi.poll(rig.field, poll_period_s, t_start - poll_period_s, t_end + poll_period_s);
    // integrate reported power over the kernel window, as-is
    let p_smi = mean_power(&log.series, t_start, t_end);
    let duration = t_end - t_start;
    let energy_j = p_smi * duration;
    let truth_j = cap.pmd_trace.energy_between(t_start, t_end);
    NaiveResult {
        energy_j,
        truth_j,
        pct_error: pct_error(energy_j, truth_j),
        mean_power_w: p_smi,
        window_s: duration,
    }
}

/// [`measure_naive`] on the streaming pipeline: same seeds, same polling,
/// same integration — through reused scratch buffers and without
/// materialising the ground-truth trace.
pub fn measure_naive_streaming<L: RepeatableLoad>(
    rig: &MeasurementRig,
    load: &L,
    poll_period_s: f64,
    run_seed: u64,
    scratch: &mut MeasureScratch,
) -> NaiveResult {
    let mut rng = Rng::new(rig.seed ^ run_seed);
    let t_start = 0.5 + rng.uniform();
    let mut activity = std::mem::take(&mut scratch.activity);
    load.build_into(t_start, 1, 0, 0.0, &mut activity);
    let t_end = activity.t_end();
    let boot_seed = rig.seed ^ run_seed ^ 0xB001;
    let meta = capture_streaming(rig, &activity, 0.0, t_end + 0.5, boot_seed, scratch);
    scratch.activity = activity;

    scratch.points.clear();
    poll_readings(
        &scratch.readings,
        Rng::new(boot_seed ^ 0x5149),
        poll_period_s,
        0.15,
        t_start - poll_period_s,
        t_end + poll_period_s,
        &mut scratch.points,
    );
    let p_smi = mean_power_points(&scratch.points, t_start, t_end);
    let duration = t_end - t_start;
    let energy_j = p_smi * duration;
    let truth_j = meta.pmd_view(&scratch.pmd).energy_between(t_start, t_end);
    NaiveResult {
        energy_j,
        truth_j,
        pct_error: pct_error(energy_j, truth_j),
        mean_power_w: p_smi,
        window_s: duration,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::WORKLOADS;
    use crate::bench::BenchmarkLoad;
    use crate::sim::device::GpuDevice;
    use crate::sim::profile::{find_model, DriverEpoch, PowerField};

    #[test]
    fn naive_single_run_has_substantial_error_on_a100() {
        // Case 3 (25/100): a single 100 ms iteration leaves 75% unobserved,
        // so across boot phases the naive error is large and random.
        let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, 42);
        let rig = MeasurementRig::new(device, DriverEpoch::Post530, PowerField::Instant, 1);
        let load = BenchmarkLoad::new(0.1, 1.0, 1);
        let mut errors = Vec::new();
        for s in 0..12 {
            let r = measure_naive(&rig, &load, 0.02, s);
            errors.push(r.pct_error.abs());
        }
        let max = errors.iter().cloned().fold(0.0, f64::max);
        assert!(max > 10.0, "naive A100 error should spike, max={max:.1}%");
    }

    #[test]
    fn naive_reports_positive_energy() {
        // V530 driver: 100 ms window, so a single 0.4 s run reads plausibly
        let device = GpuDevice::new(find_model("RTX 3090").unwrap(), 0, 9);
        let rig = MeasurementRig::new(device, DriverEpoch::V530, PowerField::Draw, 2);
        let load = BenchmarkLoad::new(0.4, 1.0, 1);
        let r = measure_naive(&rig, &load, 0.02, 3);
        assert!(r.energy_j > 0.0 && r.truth_j > 0.0);
        assert!(r.mean_power_w > 50.0);
        assert!((r.window_s - 0.4).abs() < 1e-9);
    }

    #[test]
    fn naive_underestimates_with_1s_average_window() {
        // Case 2: 1 s averaging window on a short program -> the reading
        // ramps up and the single-run integral underestimates badly.
        let device = GpuDevice::new(find_model("RTX 3090").unwrap(), 0, 17);
        let rig = MeasurementRig::new(device, DriverEpoch::Pre530, PowerField::Draw, 5);
        let load = BenchmarkLoad::new(0.8, 1.0, 1); // 0.4 s busy
        let mut mean_err = 0.0;
        for s in 0..8 {
            mean_err += measure_naive(&rig, &load, 0.02, 100 + s).pct_error;
        }
        mean_err /= 8.0;
        assert!(mean_err < -20.0, "1 s window must underestimate, got {mean_err:.1}%");
    }

    #[test]
    fn streaming_matches_materialized_bit_for_bit() {
        let mut scratch = MeasureScratch::new();
        for (model, driver, field, seed) in [
            ("A100 PCIe-40G", DriverEpoch::Post530, PowerField::Instant, 7u64),
            ("RTX 3090", DriverEpoch::Pre530, PowerField::Draw, 8),
            ("V100 PCIe-16G", DriverEpoch::Pre530, PowerField::Draw, 9),
            ("Tesla K40", DriverEpoch::Pre530, PowerField::Draw, 10),
        ] {
            let device = GpuDevice::new(find_model(model).unwrap(), 0, seed);
            let rig = MeasurementRig::new(device, driver, field, seed ^ 0xFEED);
            for (w, wl) in WORKLOADS.iter().enumerate().take(3) {
                let a = measure_naive(&rig, wl, 0.02, w as u64);
                // scratch deliberately reused across models and workloads
                let b = measure_naive_streaming(&rig, wl, 0.02, w as u64, &mut scratch);
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{model}/{}", wl.name);
                assert_eq!(a.truth_j.to_bits(), b.truth_j.to_bits(), "{model}/{}", wl.name);
                assert_eq!(a.pct_error.to_bits(), b.pct_error.to_bits(), "{model}/{}", wl.name);
                assert_eq!(a.mean_power_w.to_bits(), b.mean_power_w.to_bits(), "{model}/{}", wl.name);
                assert_eq!(a.window_s.to_bits(), b.window_s.to_bits(), "{model}/{}", wl.name);
            }
        }
    }
}
