//! Energy integration primitives shared by the naive and good-practice
//! measurement paths.

use crate::sim::trace::SampleSeries;

/// Trapezoidal energy (J) of a polled power series over `[t0, t1]`,
/// clipping boundary segments to the interval (partial segments count
/// proportionally — matches integrating the zero-order-hold signal).
pub fn integrate_clipped(series: &SampleSeries, t0: f64, t1: f64) -> f64 {
    let mut e = 0.0;
    for w in series.points.windows(2) {
        let (ta, pa) = w[0];
        let (tb, pb) = w[1];
        if tb <= t0 || ta >= t1 {
            continue;
        }
        let lo = ta.max(t0);
        let hi = tb.min(t1);
        if hi <= lo {
            continue;
        }
        // linear interpolation of power at the clipped endpoints
        let frac = |t: f64| (t - ta) / (tb - ta);
        let p_lo = pa + (pb - pa) * frac(lo);
        let p_hi = pa + (pb - pa) * frac(hi);
        e += 0.5 * (p_lo + p_hi) * (hi - lo);
    }
    e
}

/// Mean power (W) of a series over `[t0, t1]` by clipped integration.
pub fn mean_power(series: &SampleSeries, t0: f64, t1: f64) -> f64 {
    let d = t1 - t0;
    if d <= 0.0 {
        return 0.0;
    }
    integrate_clipped(series, t0, t1) / d
}

/// Shift every timestamp earlier by `shift_s` (the paper's boxcar-latency
/// compensation: "the reported power draw actually corresponds to the GPU
/// activity from [window] prior").
pub fn shift_earlier(series: &SampleSeries, shift_s: f64) -> SampleSeries {
    SampleSeries { points: series.points.iter().map(|&(t, p)| (t - shift_s, p)).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(p: f64, n: usize, dt: f64) -> SampleSeries {
        SampleSeries { points: (0..n).map(|i| (i as f64 * dt, p)).collect() }
    }

    #[test]
    fn clipped_integration_full_range() {
        let s = flat(100.0, 11, 0.1); // 0..1.0 s
        assert!((integrate_clipped(&s, 0.0, 1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn clipped_integration_partial_segments() {
        let s = flat(100.0, 11, 0.1);
        // [0.05, 0.95]: 0.9 s of 100 W
        assert!((integrate_clipped(&s, 0.05, 0.95) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn clip_interpolates_ramp() {
        let s = SampleSeries { points: vec![(0.0, 0.0), (1.0, 100.0)] };
        // over [0.5, 1.0]: mean power 75 W -> 37.5 J
        assert!((integrate_clipped(&s, 0.5, 1.0) - 37.5).abs() < 1e-9);
    }

    #[test]
    fn mean_power_flat() {
        let s = flat(250.0, 101, 0.01);
        assert!((mean_power(&s, 0.2, 0.8) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn shift_earlier_moves_times() {
        let s = flat(10.0, 3, 1.0);
        let sh = shift_earlier(&s, 0.5);
        assert_eq!(sh.points[0].0, -0.5);
        assert_eq!(sh.points[2].0, 1.5);
    }

    #[test]
    fn out_of_range_is_zero() {
        let s = flat(100.0, 5, 0.1);
        assert_eq!(integrate_clipped(&s, 10.0, 11.0), 0.0);
        assert_eq!(mean_power(&s, 1.0, 1.0), 0.0);
    }
}
