//! Energy integration primitives shared by the naive and good-practice
//! measurement paths. Each primitive has a `_points` form over a raw
//! `(t, W)` slice — the streaming pipeline integrates scratch buffers
//! through those — and a [`SampleSeries`] wrapper that delegates to it, so
//! both paths run the identical arithmetic.
//!
//! Every integration entry point — the tuple-slice reference, the
//! columnar (structure-of-arrays) form the telemetry hot path streams
//! through, and the per-pair fast path inside
//! `telemetry::accounting::NodeAccountant` — funnels into one
//! branch-free segment kernel, [`trapezoid_clipped`]. Contributions are
//! always *accumulated in stream order*, one segment at a time, no
//! matter how the computation is chunked; that single discipline is what
//! keeps streaming, batched, and vectorised results bit-for-bit
//! identical for every chunk width and batch size.

use crate::sim::trace::SampleSeries;

/// Default block width for the chunked columnar accumulation
/// ([`integrate_clipped_columns`]): segment kernels are evaluated
/// `INTEGRATE_CHUNK` at a time with no cross-lane dependency (the block
/// auto-vectorises), then folded into the accumulator in stream order.
pub const INTEGRATE_CHUNK: usize = 8;

/// Largest block width [`integrate_clipped_columns_width`] accepts (the
/// lane buffer lives on the stack).
pub const INTEGRATE_CHUNK_MAX: usize = 64;

/// One clipped trapezoid segment: the energy contribution of the sample
/// pair `(ta, pa) → (tb, pb)` over `[t0, t1]`, or exactly `0.0` when the
/// segment lies outside the interval or is degenerate (`tb <= ta`).
///
/// Branch-free: the contribution is computed unconditionally and a
/// select masks it to zero, so blocks of segments evaluate with no
/// data-dependent control flow. The arithmetic is op-for-op the
/// historical `integrate_clipped_points` loop body (same max/min clips,
/// same `(t - ta) / (tb - ta)` interpolation, same multiply/add order),
/// which is what keeps every caller bit-compatible with the committed
/// golden fixtures.
#[inline(always)]
pub fn trapezoid_clipped(ta: f64, pa: f64, tb: f64, pb: f64, t0: f64, t1: f64) -> f64 {
    let lo = ta.max(t0);
    let hi = tb.min(t1);
    let dp = pb - pa;
    let dt = tb - ta;
    // linear interpolation of power at the clipped endpoints
    let p_lo = pa + dp * ((lo - ta) / dt);
    let p_hi = pa + dp * ((hi - ta) / dt);
    let v = 0.5 * (p_lo + p_hi) * (hi - lo);
    // same skip set as the historical branching loop; `v` may be NaN for
    // a degenerate pair, but a skipped lane contributes a literal 0.0
    let skip = (tb <= t0) | (ta >= t1) | (hi <= lo);
    if skip {
        0.0
    } else {
        v
    }
}

/// Trapezoidal energy (J) of a polled `(t, W)` slice over `[t0, t1]`,
/// clipping boundary segments to the interval (partial segments count
/// proportionally — matches integrating the zero-order-hold signal).
pub fn integrate_clipped_points(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    let mut e = 0.0;
    for w in points.windows(2) {
        let (ta, pa) = w[0];
        let (tb, pb) = w[1];
        e += trapezoid_clipped(ta, pa, tb, pb, t0, t1);
    }
    e
}

/// [`integrate_clipped_points`] over columnar (structure-of-arrays)
/// samples — the telemetry hot path's layout. Bit-for-bit equal to the
/// tuple-slice reference on the zipped input, for any data: segments are
/// evaluated in blocks of [`INTEGRATE_CHUNK`] (branch-free, so the block
/// vectorises) but folded into the accumulator strictly in stream order.
pub fn integrate_clipped_columns(ts: &[f64], watts: &[f64], t0: f64, t1: f64) -> f64 {
    integrate_clipped_columns_width(ts, watts, t0, t1, INTEGRATE_CHUNK)
}

/// [`integrate_clipped_columns`] with an explicit block width in
/// `[1, INTEGRATE_CHUNK_MAX]` (clamped). The width changes only how the
/// segment kernels are grouped for evaluation, never the accumulation
/// order, so every width returns identical bits — the property the
/// vectorised-vs-scalar tests pin.
pub fn integrate_clipped_columns_width(
    ts: &[f64],
    watts: &[f64],
    t0: f64,
    t1: f64,
    width: usize,
) -> f64 {
    debug_assert_eq!(ts.len(), watts.len());
    let n = ts.len().min(watts.len());
    if n < 2 {
        return 0.0;
    }
    let width = width.clamp(1, INTEGRATE_CHUNK_MAX);
    let mut lanes = [0.0f64; INTEGRATE_CHUNK_MAX];
    let mut e = 0.0;
    let pairs = n - 1;
    let mut i = 0;
    while i < pairs {
        let m = width.min(pairs - i);
        // branch-free lane evaluation: no cross-lane dependency
        for k in 0..m {
            lanes[k] = trapezoid_clipped(ts[i + k], watts[i + k], ts[i + k + 1], watts[i + k + 1], t0, t1);
        }
        // sequential fold in stream order: bit-identical for every width
        for &lane in &lanes[..m] {
            e += lane;
        }
        i += m;
    }
    e
}

/// [`integrate_clipped_points`] over a [`SampleSeries`].
pub fn integrate_clipped(series: &SampleSeries, t0: f64, t1: f64) -> f64 {
    integrate_clipped_points(&series.points, t0, t1)
}

/// Mean power (W) of a `(t, W)` slice over `[t0, t1]` by clipped
/// integration; 0 for empty or inverted intervals.
pub fn mean_power_points(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    let d = t1 - t0;
    if d <= 0.0 {
        return 0.0;
    }
    integrate_clipped_points(points, t0, t1) / d
}

/// [`mean_power_points`] over a [`SampleSeries`].
pub fn mean_power(series: &SampleSeries, t0: f64, t1: f64) -> f64 {
    mean_power_points(&series.points, t0, t1)
}

/// Shift every timestamp earlier by `shift_s` into a caller-owned buffer
/// (cleared first) — the paper's boxcar-latency compensation without a
/// per-trial allocation.
pub fn shift_earlier_into(points: &[(f64, f64)], shift_s: f64, out: &mut Vec<(f64, f64)>) {
    out.clear();
    out.extend(points.iter().map(|&(t, p)| (t - shift_s, p)));
}

/// Shift every timestamp earlier by `shift_s` (the paper's boxcar-latency
/// compensation: "the reported power draw actually corresponds to the GPU
/// activity from [window] prior").
pub fn shift_earlier(series: &SampleSeries, shift_s: f64) -> SampleSeries {
    let mut points = Vec::with_capacity(series.points.len());
    shift_earlier_into(&series.points, shift_s, &mut points);
    SampleSeries { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(p: f64, n: usize, dt: f64) -> SampleSeries {
        SampleSeries { points: (0..n).map(|i| (i as f64 * dt, p)).collect() }
    }

    #[test]
    fn clipped_integration_full_range() {
        let s = flat(100.0, 11, 0.1); // 0..1.0 s
        assert!((integrate_clipped(&s, 0.0, 1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn clipped_integration_partial_segments() {
        let s = flat(100.0, 11, 0.1);
        // [0.05, 0.95]: 0.9 s of 100 W
        assert!((integrate_clipped(&s, 0.05, 0.95) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn clip_interpolates_ramp() {
        let s = SampleSeries { points: vec![(0.0, 0.0), (1.0, 100.0)] };
        // over [0.5, 1.0]: mean power 75 W -> 37.5 J
        assert!((integrate_clipped(&s, 0.5, 1.0) - 37.5).abs() < 1e-9);
    }

    #[test]
    fn mean_power_flat() {
        let s = flat(250.0, 101, 0.01);
        assert!((mean_power(&s, 0.2, 0.8) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn shift_earlier_moves_times() {
        let s = flat(10.0, 3, 1.0);
        let sh = shift_earlier(&s, 0.5);
        assert_eq!(sh.points[0].0, -0.5);
        assert_eq!(sh.points[2].0, 1.5);
    }

    #[test]
    fn shift_earlier_into_reuses_buffer() {
        let s = flat(10.0, 4, 1.0);
        let mut buf = Vec::new();
        shift_earlier_into(&s.points, 0.25, &mut buf);
        assert_eq!(buf, shift_earlier(&s, 0.25).points);
        let cap = buf.capacity();
        shift_earlier_into(&s.points, 0.5, &mut buf);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf[0].0, -0.5);
    }

    #[test]
    fn out_of_range_is_zero() {
        let s = flat(100.0, 5, 0.1);
        assert_eq!(integrate_clipped(&s, 10.0, 11.0), 0.0);
        assert_eq!(mean_power(&s, 1.0, 1.0), 0.0);
    }

    #[test]
    fn empty_series_is_zero() {
        let s = SampleSeries::default();
        assert_eq!(integrate_clipped(&s, 0.0, 1.0), 0.0);
        assert_eq!(mean_power(&s, 0.0, 1.0), 0.0);
        let mut buf = vec![(1.0, 2.0)];
        shift_earlier_into(&s.points, 0.1, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn single_point_series_is_zero() {
        // one sample spans no interval: no trapezoid to integrate
        let s = SampleSeries { points: vec![(0.5, 120.0)] };
        assert_eq!(integrate_clipped(&s, 0.0, 1.0), 0.0);
        assert_eq!(mean_power(&s, 0.0, 1.0), 0.0);
    }

    #[test]
    fn inverted_interval_is_zero() {
        let s = flat(100.0, 11, 0.1);
        assert_eq!(integrate_clipped(&s, 0.8, 0.2), 0.0);
        assert_eq!(mean_power(&s, 0.8, 0.2), 0.0);
        assert_eq!(mean_power(&s, 0.5, 0.5), 0.0);
    }

    /// The historical branching loop body, kept verbatim as the oracle
    /// the branch-free kernel must reproduce bit-for-bit.
    fn scalar_reference(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
        let mut e = 0.0;
        for w in points.windows(2) {
            let (ta, pa) = w[0];
            let (tb, pb) = w[1];
            if tb <= t0 || ta >= t1 {
                continue;
            }
            let lo = ta.max(t0);
            let hi = tb.min(t1);
            if hi <= lo {
                continue;
            }
            let frac = |t: f64| (t - ta) / (tb - ta);
            let p_lo = pa + (pb - pa) * frac(lo);
            let p_hi = pa + (pb - pa) * frac(hi);
            e += 0.5 * (p_lo + p_hi) * (hi - lo);
        }
        e
    }

    /// Adversarial sample sets for the vectorised-vs-scalar pin: jittered
    /// grids, identical timestamps, epsilon-spaced points, denormal
    /// powers and spacings, and segments straddling the clip edges.
    fn adversarial_cases() -> Vec<(Vec<(f64, f64)>, f64, f64)> {
        let mut rng = crate::rng::Rng::new(0x1f2e3d4c);
        let mut cases: Vec<(Vec<(f64, f64)>, f64, f64)> = Vec::new();

        // jittered grid with duplicate timestamps spliced in
        let mut jittered: Vec<(f64, f64)> = Vec::new();
        let mut t = 0.0;
        for _ in 0..257 {
            t += rng.uniform() * 0.004;
            jittered.push((t, 50.0 + 300.0 * rng.uniform()));
            if rng.uniform() < 0.15 {
                // identical timestamp, different power: degenerate pair
                jittered.push((t, 50.0 + 300.0 * rng.uniform()));
            }
        }
        cases.push((jittered, 0.1, 0.45));

        // epsilon-spaced points hugging a bucket edge at t = 1.0
        let eps = f64::EPSILON;
        let hug: Vec<(f64, f64)> = (0..64)
            .map(|i| (1.0 - 32.0 * eps + i as f64 * eps, 100.0 + i as f64))
            .collect();
        cases.push((hug, 0.0, 1.0));

        // denormal powers and denormal spacing
        let tiny = f64::MIN_POSITIVE / 8.0; // subnormal
        let denorm: Vec<(f64, f64)> = (0..33)
            .map(|i| (i as f64 * tiny, if i % 2 == 0 { tiny } else { -tiny }))
            .collect();
        cases.push((denorm, 0.0, 20.0 * tiny));

        // segments straddling both clip edges, including fully outside
        let straddle = vec![
            (-1.0, 10.0),
            (0.5, 20.0),   // straddles t0 = 0.0? (t0 below) — clipped at lo
            (0.999, 30.0), // straddles the t1 edge
            (1.5, 40.0),
            (2.0, 50.0), // entirely past t1
        ];
        cases.push((straddle, 0.0, 1.0));

        // empty / single-point / inverted-range degenerates
        cases.push((Vec::new(), 0.0, 1.0));
        cases.push((vec![(0.5, 100.0)], 0.0, 1.0));
        cases.push((vec![(0.0, 1.0), (1.0, 2.0)], 0.9, 0.1));
        cases
    }

    /// The tentpole's determinism discipline, pinned: the branch-free
    /// kernel path equals the historical branching loop bit-for-bit on
    /// adversarial inputs, and the columnar form returns identical bits
    /// for *every* block width.
    #[test]
    fn vectorised_integration_matches_scalar_bitwise_for_every_chunk_width() {
        for (points, t0, t1) in adversarial_cases() {
            let want = scalar_reference(&points, t0, t1);
            let got = integrate_clipped_points(&points, t0, t1);
            assert_eq!(got.to_bits(), want.to_bits(), "kernel path diverged (n={})", points.len());

            let ts: Vec<f64> = points.iter().map(|p| p.0).collect();
            let watts: Vec<f64> = points.iter().map(|p| p.1).collect();
            assert_eq!(
                integrate_clipped_columns(&ts, &watts, t0, t1).to_bits(),
                want.to_bits(),
                "columnar default width diverged (n={})",
                points.len()
            );
            for width in (1..=17).chain([31, 32, 33, INTEGRATE_CHUNK_MAX, usize::MAX]) {
                let got = integrate_clipped_columns_width(&ts, &watts, t0, t1, width);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "width {width} diverged on {} points over [{t0}, {t1}]",
                    points.len()
                );
            }
        }
    }

    /// The per-pair kernel alone (the accounting fast path's unit of
    /// arithmetic) equals a two-point reference call on the same pair.
    #[test]
    fn pair_kernel_matches_two_point_reference() {
        let pairs = [
            ((0.0, 100.0), (0.5, 200.0), 0.0, 1.0),
            ((0.2, 5.0), (0.2, 9.0), 0.0, 1.0), // identical timestamps
            ((0.9, 50.0), (1.1, 70.0), 0.0, 1.0), // straddles t1
            ((-0.3, 10.0), (0.1, 20.0), 0.0, 1.0), // straddles t0
            ((2.0, 10.0), (3.0, 20.0), 0.0, 1.0), // fully outside
        ];
        for ((ta, pa), (tb, pb), t0, t1) in pairs {
            let want = scalar_reference(&[(ta, pa), (tb, pb)], t0, t1);
            assert_eq!(trapezoid_clipped(ta, pa, tb, pb, t0, t1).to_bits(), want.to_bits());
        }
    }
}
