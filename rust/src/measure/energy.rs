//! Energy integration primitives shared by the naive and good-practice
//! measurement paths. Each primitive has a `_points` form over a raw
//! `(t, W)` slice — the streaming pipeline integrates scratch buffers
//! through those — and a [`SampleSeries`] wrapper that delegates to it, so
//! both paths run the identical arithmetic.

use crate::sim::trace::SampleSeries;

/// Trapezoidal energy (J) of a polled `(t, W)` slice over `[t0, t1]`,
/// clipping boundary segments to the interval (partial segments count
/// proportionally — matches integrating the zero-order-hold signal).
pub fn integrate_clipped_points(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    let mut e = 0.0;
    for w in points.windows(2) {
        let (ta, pa) = w[0];
        let (tb, pb) = w[1];
        if tb <= t0 || ta >= t1 {
            continue;
        }
        let lo = ta.max(t0);
        let hi = tb.min(t1);
        if hi <= lo {
            continue;
        }
        // linear interpolation of power at the clipped endpoints
        let frac = |t: f64| (t - ta) / (tb - ta);
        let p_lo = pa + (pb - pa) * frac(lo);
        let p_hi = pa + (pb - pa) * frac(hi);
        e += 0.5 * (p_lo + p_hi) * (hi - lo);
    }
    e
}

/// [`integrate_clipped_points`] over a [`SampleSeries`].
pub fn integrate_clipped(series: &SampleSeries, t0: f64, t1: f64) -> f64 {
    integrate_clipped_points(&series.points, t0, t1)
}

/// Mean power (W) of a `(t, W)` slice over `[t0, t1]` by clipped
/// integration; 0 for empty or inverted intervals.
pub fn mean_power_points(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    let d = t1 - t0;
    if d <= 0.0 {
        return 0.0;
    }
    integrate_clipped_points(points, t0, t1) / d
}

/// [`mean_power_points`] over a [`SampleSeries`].
pub fn mean_power(series: &SampleSeries, t0: f64, t1: f64) -> f64 {
    mean_power_points(&series.points, t0, t1)
}

/// Shift every timestamp earlier by `shift_s` into a caller-owned buffer
/// (cleared first) — the paper's boxcar-latency compensation without a
/// per-trial allocation.
pub fn shift_earlier_into(points: &[(f64, f64)], shift_s: f64, out: &mut Vec<(f64, f64)>) {
    out.clear();
    out.extend(points.iter().map(|&(t, p)| (t - shift_s, p)));
}

/// Shift every timestamp earlier by `shift_s` (the paper's boxcar-latency
/// compensation: "the reported power draw actually corresponds to the GPU
/// activity from [window] prior").
pub fn shift_earlier(series: &SampleSeries, shift_s: f64) -> SampleSeries {
    let mut points = Vec::with_capacity(series.points.len());
    shift_earlier_into(&series.points, shift_s, &mut points);
    SampleSeries { points }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(p: f64, n: usize, dt: f64) -> SampleSeries {
        SampleSeries { points: (0..n).map(|i| (i as f64 * dt, p)).collect() }
    }

    #[test]
    fn clipped_integration_full_range() {
        let s = flat(100.0, 11, 0.1); // 0..1.0 s
        assert!((integrate_clipped(&s, 0.0, 1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn clipped_integration_partial_segments() {
        let s = flat(100.0, 11, 0.1);
        // [0.05, 0.95]: 0.9 s of 100 W
        assert!((integrate_clipped(&s, 0.05, 0.95) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn clip_interpolates_ramp() {
        let s = SampleSeries { points: vec![(0.0, 0.0), (1.0, 100.0)] };
        // over [0.5, 1.0]: mean power 75 W -> 37.5 J
        assert!((integrate_clipped(&s, 0.5, 1.0) - 37.5).abs() < 1e-9);
    }

    #[test]
    fn mean_power_flat() {
        let s = flat(250.0, 101, 0.01);
        assert!((mean_power(&s, 0.2, 0.8) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn shift_earlier_moves_times() {
        let s = flat(10.0, 3, 1.0);
        let sh = shift_earlier(&s, 0.5);
        assert_eq!(sh.points[0].0, -0.5);
        assert_eq!(sh.points[2].0, 1.5);
    }

    #[test]
    fn shift_earlier_into_reuses_buffer() {
        let s = flat(10.0, 4, 1.0);
        let mut buf = Vec::new();
        shift_earlier_into(&s.points, 0.25, &mut buf);
        assert_eq!(buf, shift_earlier(&s, 0.25).points);
        let cap = buf.capacity();
        shift_earlier_into(&s.points, 0.5, &mut buf);
        assert_eq!(buf.capacity(), cap);
        assert_eq!(buf[0].0, -0.5);
    }

    #[test]
    fn out_of_range_is_zero() {
        let s = flat(100.0, 5, 0.1);
        assert_eq!(integrate_clipped(&s, 10.0, 11.0), 0.0);
        assert_eq!(mean_power(&s, 1.0, 1.0), 0.0);
    }

    #[test]
    fn empty_series_is_zero() {
        let s = SampleSeries::default();
        assert_eq!(integrate_clipped(&s, 0.0, 1.0), 0.0);
        assert_eq!(mean_power(&s, 0.0, 1.0), 0.0);
        let mut buf = vec![(1.0, 2.0)];
        shift_earlier_into(&s.points, 0.1, &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn single_point_series_is_zero() {
        // one sample spans no interval: no trapezoid to integrate
        let s = SampleSeries { points: vec![(0.5, 120.0)] };
        assert_eq!(integrate_clipped(&s, 0.0, 1.0), 0.0);
        assert_eq!(mean_power(&s, 0.0, 1.0), 0.0);
    }

    #[test]
    fn inverted_interval_is_zero() {
        let s = flat(100.0, 11, 0.1);
        assert_eq!(integrate_clipped(&s, 0.8, 0.2), 0.0);
        assert_eq!(mean_power(&s, 0.8, 0.2), 0.0);
        assert_eq!(mean_power(&s, 0.5, 0.5), 0.0);
    }
}
