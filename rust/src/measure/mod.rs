//! The paper's contribution: energy measurement via nvidia-smi, done right.
//!
//! * [`naive`] — what the surveyed literature does: run the program once,
//!   integrate whatever nvidia-smi reports over the kernel window (errors
//!   up to ~70%, Fig. 18).
//! * [`good_practice`] — the paper's §5.1 procedure: ≥32 repetitions or
//!   ≥5 s, controlled phase-shift delays when the averaging window
//!   undersamples, multiple randomised trials, rise-time discard, boxcar
//!   latency shift, and the optional steady-state linear correction.
//! * [`correction`] — the Fig. 8 gradient/offset inversion.
//!
//! The [`MeasurementRig`] owns the simulated card + instrument pairing and
//! the [`SensorCharacterization`] describes what the micro-benchmarks
//! learned about the sensor — the measurement procedures consume only
//! those learned parameters, never the simulator's hidden ground truth.
//!
//! Every procedure exists in two forms that are **bit-for-bit identical**
//! for a fixed seed (pinned by tests):
//! * the materialised reference path (`measure_naive`,
//!   `measure_good_practice`) — captures a full [`PowerTrace`] plus an
//!   [`NvidiaSmi`] per run, as the experiments always have;
//! * the streaming path (`measure_naive_streaming`,
//!   `measure_good_practice_streaming`) — drives a chunked
//!   [`crate::sim::TraceSampler`] through a per-worker [`MeasureScratch`]
//!   arena, doing O(chunk) allocation per node instead of O(trace).

// The trapezoid integration kernel and streaming capture live here: keep
// the perf lint family blocking on the whole module tree.
#![deny(clippy::perf)]

pub mod correction;
pub mod energy;
pub mod good_practice;
pub mod naive;

pub use correction::PowerCorrection;
pub use good_practice::{measure_good_practice_streaming, GoodPracticeConfig, GoodPracticeResult};
pub use naive::{measure_naive_streaming, NaiveResult};

use crate::pmd::Pmd;
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{sensor_pipeline, DriverEpoch, PowerField};
use crate::sim::sensor::{lookback_samples, Reading, SensorConsumer};
use crate::sim::trace::{
    PowerTrace, SampleSource, SamplerBuffers, TraceSampler, TraceView, STREAM_CHUNK, TRUE_HZ,
};
use crate::smi::NvidiaSmi;

/// A device + driver + instrument pairing for one measurement campaign.
#[derive(Debug)]
pub struct MeasurementRig {
    pub device: GpuDevice,
    pub driver: DriverEpoch,
    pub field: PowerField,
    pub pmd: Pmd,
    /// Campaign seed (trial boot phases and alignment delays derive from it).
    pub seed: u64,
}

/// One realised capture: ground truth + both instruments.
#[derive(Debug)]
pub struct Capture {
    pub truth: PowerTrace,
    pub smi: NvidiaSmi,
    pub pmd_trace: PowerTrace,
}

impl MeasurementRig {
    pub fn new(device: GpuDevice, driver: DriverEpoch, field: PowerField, seed: u64) -> Self {
        let pmd = Pmd::new(seed ^ 0xBEEF);
        MeasurementRig { device, driver, field, pmd, seed }
    }

    /// Run a workload (as an activity signal) on the simulated card and
    /// capture both the nvidia-smi view and the PMD ground truth.
    pub fn capture(&self, activity: &ActivitySignal, t0: f64, t1: f64, boot_seed: u64) -> Capture {
        let truth = self.device.synthesize(activity, t0, t1);
        let smi = NvidiaSmi::attach(self.device.clone(), self.driver, &truth, boot_seed);
        let pmd_trace = self.pmd.measure(&self.device, &truth);
        Capture { truth, smi, pmd_trace }
    }
}

/// Per-worker scratch arena for the streaming measurement pipeline: every
/// buffer a capture needs, reused across nodes so a 1k–10k-node campaign
/// allocates O(chunk) once per worker rather than O(trace) per node.
#[derive(Debug, Default)]
pub struct MeasureScratch {
    /// TraceSampler chunk + prefix-ring allocations (taken/returned per capture).
    bufs: Option<SamplerBuffers>,
    /// Realised sensor readings for the rig's queried field.
    pub(crate) readings: Vec<Reading>,
    /// PMD samples for the capture window.
    pub(crate) pmd: Vec<f32>,
    /// Inclusive prefix sums over `pmd` (good-practice truth windows).
    pub(crate) pmd_prefix: Vec<f64>,
    /// Polled `(t, W)` series.
    pub(crate) points: Vec<(f64, f64)>,
    /// Boxcar-latency-shifted (and optionally corrected) series.
    pub(crate) shifted: Vec<(f64, f64)>,
    /// Reusable activity signal built per trial.
    pub(crate) activity: ActivitySignal,
    /// Per-trial percentage errors (good practice).
    pub(crate) trial_errors: Vec<f64>,
    /// Per-trial mean powers (good practice).
    pub(crate) powers: Vec<f64>,
}

impl MeasureScratch {
    /// Fresh arena (all buffers grow on first use, then stay).
    pub fn new() -> Self {
        MeasureScratch::default()
    }
}

/// Geometry of the PMD samples a streaming capture produced.
#[derive(Debug, Clone, Copy)]
pub(crate) struct CaptureMeta {
    pub pmd_hz: f64,
    pub pmd_t0: f64,
}

impl CaptureMeta {
    /// View the scratch PMD samples as a trace.
    pub fn pmd_view<'a>(&self, pmd: &'a [f32]) -> TraceView<'a> {
        TraceView { hz: self.pmd_hz, t0: self.pmd_t0, samples: pmd }
    }
}

/// Streaming equivalent of [`MeasurementRig::capture`]: one chunked pass
/// over the synthesised ground truth feeding (a) the sensor pipeline of
/// the rig's queried field and (b) the PMD decimator, into reused scratch
/// buffers. Produces bit-for-bit the readings/PMD samples the materialised
/// capture yields for the same seeds (the per-field boot-seed tag makes
/// the three field streams independent, so realising one is enough).
pub(crate) fn capture_streaming(
    rig: &MeasurementRig,
    activity: &ActivitySignal,
    t0: f64,
    t1: f64,
    boot_seed: u64,
    scratch: &mut MeasureScratch,
) -> CaptureMeta {
    scratch.readings.clear();
    scratch.pmd.clear();
    capture_streaming_append(rig, activity, t0, t1, boot_seed, scratch)
}

/// [`capture_streaming`] without clearing the scratch readings/PMD buffers
/// first: the telemetry `SimSource` captures a node's observation as a
/// *sequence* of sensor epochs (a driver restart re-boots the sensor with a
/// fresh phase mid-stream, §4.3) and concatenates the segments. Segment
/// boundaries must land on the PMD sample grid for the concatenated PMD
/// buffer to stay a uniform trace (the caller snaps them).
pub(crate) fn capture_streaming_append(
    rig: &MeasurementRig,
    activity: &ActivitySignal,
    t0: f64,
    t1: f64,
    boot_seed: u64,
    scratch: &mut MeasureScratch,
) -> CaptureMeta {
    let spec = sensor_pipeline(rig.device.model.generation, rig.field, rig.driver);
    let source = rig.device.synth_stream(activity, t0, t1);
    let hz = TRUE_HZ;
    let total_len = source.total_len();
    let mut sampler = TraceSampler::with_buffers(
        source,
        lookback_samples(&spec, hz),
        STREAM_CHUNK,
        scratch.bufs.take().unwrap_or_default(),
    );
    let mut sensor = SensorConsumer::new(
        &rig.device,
        spec,
        hz,
        t0,
        total_len,
        boot_seed ^ crate::smi::field_tag(rig.field),
        STREAM_CHUNK,
    );
    let mut pmd = rig.pmd.stream(&rig.device, hz);
    while sampler.advance() {
        sensor.push_chunk(sampler.chunk(), sampler.prefix(), &mut scratch.readings);
        pmd.push_chunk(sampler.chunk(), sampler.chunk_start(), &mut scratch.pmd);
    }
    let meta = CaptureMeta { pmd_hz: pmd.out_hz, pmd_t0: t0 };
    scratch.bufs = Some(sampler.into_buffers());
    meta
}

/// Mean PMD power over `[t0, t1]` from precomputed inclusive prefix sums —
/// the good-practice truth reference, shared verbatim by the materialised
/// and streaming paths so the arithmetic can never drift between them.
/// (Historical quirk, kept for reproducibility: with `base = prefix[i0-1]`
/// the sum spans `i1 - i0 + 1` samples while the divisor is `i1 - i0`; at
/// the thousands of samples a window covers the bias is negligible.)
pub(crate) fn pmd_window_mean(prefix: &[f64], view: TraceView<'_>, t0: f64, t1: f64) -> f64 {
    let i0 = view.index_of(t0);
    let i1 = view.index_of(t1);
    let n = (i1 - i0).max(1) as f64;
    let base = if i0 == 0 { 0.0 } else { prefix[i0 - 1] };
    (prefix[i1] - base) / n
}

/// What the micro-benchmark characterisation learned about a sensor —
/// the only knowledge the good-practice procedure is allowed to use.
#[derive(Debug, Clone, Copy)]
pub struct SensorCharacterization {
    /// Power update period, seconds (Fig. 6 experiment).
    pub update_s: f64,
    /// Boxcar averaging window, seconds (Fig. 12 experiment).
    pub window_s: f64,
    /// Board power rise time, seconds (Fig. 7 experiment).
    pub rise_s: f64,
}

impl SensorCharacterization {
    /// True when the window undersamples the update period — the paper's
    /// "data loss" condition requiring controlled phase shifts (Case 3).
    pub fn has_data_loss(&self) -> bool {
        self.window_s < 0.9 * self.update_s
    }
}

/// A load that can be repeated N times with optional phase-shift delays —
/// implemented by both the micro-benchmark square wave and the Table 2
/// workload signatures.
pub trait RepeatableLoad {
    /// One iteration's duration, seconds.
    fn iteration_s(&self) -> f64;
    /// Name for reports.
    fn name(&self) -> &str;
    /// Build the activity for `reps` iterations starting at `t_start`,
    /// inserting a `shift_s` pause after every `reps_per_shift` iterations
    /// (0 = no shifts).
    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64)
        -> ActivitySignal;
    /// [`Self::build`] into a caller-owned signal (cleared first), so the
    /// streaming pipeline reuses one segment allocation per worker. Must
    /// produce exactly the segments `build` produces.
    fn build_into(
        &self,
        t_start: f64,
        reps: usize,
        reps_per_shift: usize,
        shift_s: f64,
        out: &mut ActivitySignal,
    ) {
        *out = self.build(t_start, reps, reps_per_shift, shift_s);
    }
}

impl RepeatableLoad for crate::bench::BenchmarkLoad {
    fn iteration_s(&self) -> f64 {
        self.period_s
    }
    fn name(&self) -> &str {
        "benchmark_load"
    }
    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64) -> ActivitySignal {
        let mut b = *self;
        b.t_start = t_start;
        b.cycles = reps;
        b.activity_with_shifts(reps_per_shift, shift_s)
    }
    fn build_into(
        &self,
        t_start: f64,
        reps: usize,
        reps_per_shift: usize,
        shift_s: f64,
        out: &mut ActivitySignal,
    ) {
        out.segments.clear();
        let mut t = t_start;
        for k in 0..reps {
            out.push(t, self.period_s * self.duty, self.sm_fraction);
            t += self.period_s;
            if reps_per_shift > 0 && (k + 1) % reps_per_shift == 0 && k + 1 < reps {
                t += shift_s;
            }
        }
    }
}

impl RepeatableLoad for crate::bench::Workload {
    fn iteration_s(&self) -> f64 {
        Self::iteration_s(self)
    }
    fn name(&self) -> &str {
        self.name
    }
    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64) -> ActivitySignal {
        self.activity_with_shifts(t_start, reps, reps_per_shift, shift_s)
    }
    fn build_into(
        &self,
        t_start: f64,
        reps: usize,
        reps_per_shift: usize,
        shift_s: f64,
        out: &mut ActivitySignal,
    ) {
        out.segments.clear();
        let mut t = t_start;
        for k in 0..reps {
            for ph in self.pattern {
                if ph.util > 0.0 {
                    out.push(t, ph.duration_s, ph.util);
                }
                t += ph.duration_s;
            }
            if reps_per_shift > 0 && (k + 1) % reps_per_shift == 0 && k + 1 < reps {
                t += shift_s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workloads::WORKLOADS;
    use crate::bench::BenchmarkLoad;

    #[test]
    fn build_into_matches_build_for_both_load_kinds() {
        let mut out = ActivitySignal::idle();
        let bench = BenchmarkLoad::new(0.1, 0.8, 9);
        bench.build_into(0.7, 9, 2, 0.025, &mut out);
        assert_eq!(out.segments, bench.build(0.7, 9, 2, 0.025).segments);

        for wl in WORKLOADS {
            wl.build_into(1.1, 7, 3, 0.05, &mut out);
            assert_eq!(out.segments, wl.build(1.1, 7, 3, 0.05).segments, "{}", wl.name);
        }
    }

    #[test]
    fn streaming_capture_matches_materialized_capture() {
        use crate::sim::{find_model, ActivitySignal};
        for (model, driver, field) in [
            ("A100 PCIe-40G", DriverEpoch::Post530, PowerField::Instant),
            ("RTX 3090", DriverEpoch::Pre530, PowerField::Draw),
            ("Tesla K40", DriverEpoch::Pre530, PowerField::Draw),
        ] {
            let device = GpuDevice::new(find_model(model).unwrap(), 0, 404);
            let rig = MeasurementRig::new(device, driver, field, 405);
            let act = ActivitySignal::square_wave(0.4, 0.09, 0.5, 1.0, 20);
            let cap = rig.capture(&act, 0.0, 2.5, 999);
            let mut scratch = MeasureScratch::new();
            let meta = capture_streaming(&rig, &act, 0.0, 2.5, 999, &mut scratch);
            assert_eq!(scratch.readings, cap.smi.stream(field).readings, "{model} readings");
            assert_eq!(scratch.pmd, cap.pmd_trace.samples, "{model} pmd");
            assert!((meta.pmd_hz - cap.pmd_trace.hz).abs() < 1e-12);
        }
    }
}
