//! The paper's contribution: energy measurement via nvidia-smi, done right.
//!
//! * [`naive`] — what the surveyed literature does: run the program once,
//!   integrate whatever nvidia-smi reports over the kernel window (errors
//!   up to ~70%, Fig. 18).
//! * [`good_practice`] — the paper's §5.1 procedure: ≥32 repetitions or
//!   ≥5 s, controlled phase-shift delays when the averaging window
//!   undersamples, multiple randomised trials, rise-time discard, boxcar
//!   latency shift, and the optional steady-state linear correction.
//! * [`correction`] — the Fig. 8 gradient/offset inversion.
//!
//! The [`MeasurementRig`] owns the simulated card + instrument pairing and
//! the [`SensorCharacterization`] describes what the micro-benchmarks
//! learned about the sensor — the measurement procedures consume only
//! those learned parameters, never the simulator's hidden ground truth.

pub mod correction;
pub mod energy;
pub mod good_practice;
pub mod naive;

pub use correction::PowerCorrection;
pub use good_practice::{GoodPracticeConfig, GoodPracticeResult};
pub use naive::NaiveResult;

use crate::pmd::Pmd;
use crate::sim::activity::ActivitySignal;
use crate::sim::device::GpuDevice;
use crate::sim::profile::{DriverEpoch, PowerField};
use crate::sim::trace::PowerTrace;
use crate::smi::NvidiaSmi;

/// A device + driver + instrument pairing for one measurement campaign.
#[derive(Debug)]
pub struct MeasurementRig {
    pub device: GpuDevice,
    pub driver: DriverEpoch,
    pub field: PowerField,
    pub pmd: Pmd,
    /// Campaign seed (trial boot phases and alignment delays derive from it).
    pub seed: u64,
}

/// One realised capture: ground truth + both instruments.
#[derive(Debug)]
pub struct Capture {
    pub truth: PowerTrace,
    pub smi: NvidiaSmi,
    pub pmd_trace: PowerTrace,
}

impl MeasurementRig {
    pub fn new(device: GpuDevice, driver: DriverEpoch, field: PowerField, seed: u64) -> Self {
        let pmd = Pmd::new(seed ^ 0xBEEF);
        MeasurementRig { device, driver, field, pmd, seed }
    }

    /// Run a workload (as an activity signal) on the simulated card and
    /// capture both the nvidia-smi view and the PMD ground truth.
    pub fn capture(&self, activity: &ActivitySignal, t0: f64, t1: f64, boot_seed: u64) -> Capture {
        let truth = self.device.synthesize(activity, t0, t1);
        let smi = NvidiaSmi::attach(self.device.clone(), self.driver, &truth, boot_seed);
        let pmd_trace = self.pmd.measure(&self.device, &truth);
        Capture { truth, smi, pmd_trace }
    }
}

/// What the micro-benchmark characterisation learned about a sensor —
/// the only knowledge the good-practice procedure is allowed to use.
#[derive(Debug, Clone, Copy)]
pub struct SensorCharacterization {
    /// Power update period, seconds (Fig. 6 experiment).
    pub update_s: f64,
    /// Boxcar averaging window, seconds (Fig. 12 experiment).
    pub window_s: f64,
    /// Board power rise time, seconds (Fig. 7 experiment).
    pub rise_s: f64,
}

impl SensorCharacterization {
    /// True when the window undersamples the update period — the paper's
    /// "data loss" condition requiring controlled phase shifts (Case 3).
    pub fn has_data_loss(&self) -> bool {
        self.window_s < 0.9 * self.update_s
    }
}

/// A load that can be repeated N times with optional phase-shift delays —
/// implemented by both the micro-benchmark square wave and the Table 2
/// workload signatures.
pub trait RepeatableLoad {
    /// One iteration's duration, seconds.
    fn iteration_s(&self) -> f64;
    /// Name for reports.
    fn name(&self) -> &str;
    /// Build the activity for `reps` iterations starting at `t_start`,
    /// inserting a `shift_s` pause after every `reps_per_shift` iterations
    /// (0 = no shifts).
    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64)
        -> ActivitySignal;
}

impl RepeatableLoad for crate::bench::BenchmarkLoad {
    fn iteration_s(&self) -> f64 {
        self.period_s
    }
    fn name(&self) -> &str {
        "benchmark_load"
    }
    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64) -> ActivitySignal {
        let mut b = *self;
        b.t_start = t_start;
        b.cycles = reps;
        b.activity_with_shifts(reps_per_shift, shift_s)
    }
}

impl RepeatableLoad for crate::bench::Workload {
    fn iteration_s(&self) -> f64 {
        Self::iteration_s(self)
    }
    fn name(&self) -> &str {
        self.name
    }
    fn build(&self, t_start: f64, reps: usize, reps_per_shift: usize, shift_s: f64) -> ActivitySignal {
        self.activity_with_shifts(t_start, reps, reps_per_shift, shift_s)
    }
}
