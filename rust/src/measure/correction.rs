//! Steady-state error correction (paper §5.3).
//!
//! Each card's sensor reads `gradient·P + offset` (Fig. 8/9). Once the
//! gradient/offset are calibrated against a reference meter, applying the
//! inverse transform removes the power-domain error, leaving only the
//! time-domain error the good-practice procedure already corrected:
//! "Applying the power measurement error gradient and offset as a
//! transform on the nvidia-smi data will reduce the error to nearly zero."

use crate::estimator::linreg::{fit, LinearFit};
use crate::sim::trace::SampleSeries;

/// Calibrated power-domain correction for one card.
#[derive(Debug, Clone, Copy)]
pub struct PowerCorrection {
    /// Fitted gradient (reported / true).
    pub gradient: f64,
    /// Fitted offset, watts.
    pub offset_w: f64,
    pub r2: f64,
}

impl PowerCorrection {
    /// Identity (no correction available).
    pub fn identity() -> Self {
        PowerCorrection { gradient: 1.0, offset_w: 0.0, r2: 1.0 }
    }

    /// Build from a steady-state calibration: paired (reference W,
    /// reported W) cluster means across power levels (the Fig. 8 fit).
    pub fn from_steady_state(reference_w: &[f64], reported_w: &[f64]) -> Self {
        let f: LinearFit = fit(reference_w, reported_w);
        PowerCorrection { gradient: f.slope, offset_w: f.intercept, r2: f.r2 }
    }

    /// Correct a reported power reading back to true watts.
    #[inline]
    pub fn correct(&self, reported_w: f64) -> f64 {
        (reported_w - self.offset_w) / self.gradient
    }

    /// Correct a whole series.
    pub fn correct_series(&self, s: &SampleSeries) -> SampleSeries {
        SampleSeries { points: s.points.iter().map(|&(t, p)| (t, self.correct(p))).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_steady_state_recovers_transform() {
        let truth: Vec<f64> = vec![30.0, 80.0, 150.0, 220.0, 300.0, 380.0];
        let reported: Vec<f64> = truth.iter().map(|p| 0.96 * p + 4.0).collect();
        let c = PowerCorrection::from_steady_state(&truth, &reported);
        assert!((c.gradient - 0.96).abs() < 1e-9);
        assert!((c.offset_w - 4.0).abs() < 1e-9);
        assert!((c.correct(0.96 * 200.0 + 4.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn identity_is_noop() {
        let c = PowerCorrection::identity();
        assert_eq!(c.correct(123.0), 123.0);
    }

    #[test]
    fn correct_series_applies_pointwise() {
        let c = PowerCorrection { gradient: 2.0, offset_w: 10.0, r2: 1.0 };
        let s = SampleSeries { points: vec![(0.0, 110.0), (1.0, 210.0)] };
        let out = c.correct_series(&s);
        assert_eq!(out.points[0].1, 50.0);
        assert_eq!(out.points[1].1, 100.0);
    }
}
