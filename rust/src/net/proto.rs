//! Request/response messages layered on [`frame`](super::frame)s.
//!
//! Every message is one frame payload: a tag byte followed by
//! little-endian fields (strings and byte blobs are u32-length-prefixed,
//! `f64`s travel as IEEE-754 bits so values survive bit-for-bit).
//! Decoding is total and offset-carrying, like the frame layer: malformed
//! payloads yield a [`ProtoError`] naming the byte where decoding
//! stopped, never a panic.
//!
//! The fleet-state interchange unit is the `.gpck` checkpoint
//! ([`Checkpoint::encode`]): [`persist`] already fingerprints the
//! fleet/config/source and checksums the record, so the Snapshot response
//! ships those bytes verbatim and [`snapshot_from_checkpoint`]
//! reconstructs the query-side [`TelemetrySnapshot`] with the exact
//! recipe a checkpoint restore uses — which is what makes remote and
//! federated accounts bit-for-bit comparable to in-process ones.

use crate::obs::console::ConsoleMetrics;
use crate::report::Table;
use crate::telemetry::accounting::{BucketSpec, FleetAccounts, NodeAccount};
use crate::telemetry::ingest::IngestStats;
use crate::telemetry::persist::{
    self, Checkpoint, NodeStage, ServiceFingerprint, SourceKind,
};
use crate::telemetry::registry::{
    EpochIdentity, NodeIdentity, ProbeSchedule, Registry, SensorIdentity,
};
use crate::telemetry::service::{ControlMsg, ServiceEvent};
use crate::telemetry::TelemetrySnapshot;

use std::fmt;

/// Where and why a payload stopped decoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    /// Byte offset into the payload at which decoding stopped.
    pub offset: usize,
    /// What the decoder expected there.
    pub what: String,
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bad message at payload byte {}: {}", self.offset, self.what)
    }
}

impl std::error::Error for ProtoError {}

// ---------------------------------------------------------------- writer

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}
fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}
fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(x) => {
            put_u8(out, 1);
            put_f64(out, x);
        }
        None => put_u8(out, 0),
    }
}
fn put_bytes(out: &mut Vec<u8>, v: &[u8]) {
    put_u32(out, v.len() as u32);
    out.extend_from_slice(v);
}
fn put_str(out: &mut Vec<u8>, v: &str) {
    put_bytes(out, v.as_bytes());
}

// ---------------------------------------------------------------- reader

/// Cursor over a payload; every read names its offset on failure.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }
    fn err<T>(&self, what: &str) -> Result<T, ProtoError> {
        Err(ProtoError { offset: self.pos, what: what.to_string() })
    }
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], ProtoError> {
        if self.buf.len() - self.pos < n {
            return self.err(what);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self, what: &str) -> Result<u8, ProtoError> {
        Ok(self.take(1, what)?[0])
    }
    fn u16(&mut self, what: &str) -> Result<u16, ProtoError> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().expect("2 bytes")))
    }
    fn u32(&mut self, what: &str) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().expect("4 bytes")))
    }
    fn u64(&mut self, what: &str) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().expect("8 bytes")))
    }
    fn i64(&mut self, what: &str) -> Result<i64, ProtoError> {
        Ok(i64::from_le_bytes(self.take(8, what)?.try_into().expect("8 bytes")))
    }
    fn f64(&mut self, what: &str) -> Result<f64, ProtoError> {
        Ok(f64::from_bits(self.u64(what)?))
    }
    fn opt_f64(&mut self, what: &str) -> Result<Option<f64>, ProtoError> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.f64(what)?)),
            _ => self.err(what),
        }
    }
    /// A u32-length-prefixed blob; the length is bounded by the remaining
    /// payload, so an adversarial count cannot drive an allocation.
    fn bytes(&mut self, what: &str) -> Result<&'a [u8], ProtoError> {
        let n = self.u32(what)? as usize;
        self.take(n, what)
    }
    fn string(&mut self, what: &str) -> Result<String, ProtoError> {
        let raw = self.bytes(what)?;
        match std::str::from_utf8(raw) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => self.err(what),
        }
    }
    /// An element count for a vector about to be decoded: at least one
    /// byte per element must remain, which caps pre-allocation.
    fn count(&mut self, what: &str) -> Result<usize, ProtoError> {
        let n = self.u32(what)? as usize;
        if self.buf.len() - self.pos < n {
            return self.err(what);
        }
        Ok(n)
    }
    fn finish(&self) -> Result<(), ProtoError> {
        if self.pos != self.buf.len() {
            return Err(ProtoError {
                offset: self.pos,
                what: format!("{} trailing byte(s)", self.buf.len() - self.pos),
            });
        }
        Ok(())
    }
}

// ------------------------------------------------------- shared codecs

fn put_fingerprint(out: &mut Vec<u8>, fp: &ServiceFingerprint) {
    put_u64(out, fp.seed);
    put_u64(out, fp.n_total as u64);
    put_u64(out, fp.windows as u64);
    put_u64(out, fp.spec_n as u64);
    put_f64(out, fp.duration_s);
    put_f64(out, fp.window_s);
    put_f64(out, fp.bucket_s);
    put_f64(out, fp.poll_period_s);
    put_u8(
        out,
        match fp.source_kind {
            SourceKind::Sim => 0,
            SourceKind::Faulty => 1,
            SourceKind::Replay => 2,
        },
    );
    put_u64(out, fp.source_digest);
    put_u64(out, fp.fleet_digest);
}

fn get_fingerprint(r: &mut Reader<'_>) -> Result<ServiceFingerprint, ProtoError> {
    Ok(ServiceFingerprint {
        seed: r.u64("fingerprint.seed")?,
        n_total: r.u64("fingerprint.n_total")? as usize,
        windows: r.u64("fingerprint.windows")? as usize,
        spec_n: r.u64("fingerprint.spec_n")? as usize,
        duration_s: r.f64("fingerprint.duration_s")?,
        window_s: r.f64("fingerprint.window_s")?,
        bucket_s: r.f64("fingerprint.bucket_s")?,
        poll_period_s: r.f64("fingerprint.poll_period_s")?,
        source_kind: match r.u8("fingerprint.source_kind")? {
            0 => SourceKind::Sim,
            1 => SourceKind::Faulty,
            2 => SourceKind::Replay,
            _ => return r.err("fingerprint.source_kind"),
        },
        source_digest: r.u64("fingerprint.source_digest")?,
        fleet_digest: r.u64("fingerprint.fleet_digest")?,
    })
}

fn put_identity(out: &mut Vec<u8>, id: &SensorIdentity) {
    put_u8(out, persist::class_code(id.class));
    put_opt_f64(out, id.update_s);
    put_opt_f64(out, id.window_s);
    put_opt_f64(out, id.smi_rise_s);
}

fn get_identity(r: &mut Reader<'_>) -> Result<SensorIdentity, ProtoError> {
    let code = r.u8("identity.class")?;
    let Some(class) = persist::class_from(code) else {
        return r.err("identity.class");
    };
    Ok(SensorIdentity {
        class,
        update_s: r.opt_f64("identity.update_s")?,
        window_s: r.opt_f64("identity.window_s")?,
        smi_rise_s: r.opt_f64("identity.smi_rise_s")?,
    })
}

fn put_stats(out: &mut Vec<u8>, s: &IngestStats) {
    put_u64(out, s.nodes as u64);
    put_u64(out, s.batches);
    put_u64(out, s.readings);
    put_u64(out, s.recalibrations);
    put_u64(out, s.drift_suspected);
}

fn get_stats(r: &mut Reader<'_>) -> Result<IngestStats, ProtoError> {
    Ok(IngestStats {
        nodes: r.u64("stats.nodes")? as usize,
        batches: r.u64("stats.batches")?,
        readings: r.u64("stats.readings")?,
        recalibrations: r.u64("stats.recalibrations")?,
        drift_suspected: r.u64("stats.drift_suspected")?,
    })
}

fn put_console(out: &mut Vec<u8>, c: &ConsoleMetrics) {
    put_i64(out, c.windows_closed);
    put_i64(out, c.windows_published);
    put_u64(out, c.checkpoints_written);
    put_i64(out, c.checkpoint_age_ms);
    put_i64(out, c.event_backlog_len);
    put_u64(out, c.events_trimmed);
    put_u32(out, c.shards.len() as u32);
    for &(depth, high, deferred) in &c.shards {
        put_i64(out, depth);
        put_i64(out, high);
        put_i64(out, deferred);
    }
}

fn get_console(r: &mut Reader<'_>) -> Result<ConsoleMetrics, ProtoError> {
    let windows_closed = r.i64("console.windows_closed")?;
    let windows_published = r.i64("console.windows_published")?;
    let checkpoints_written = r.u64("console.checkpoints_written")?;
    let checkpoint_age_ms = r.i64("console.checkpoint_age_ms")?;
    let event_backlog_len = r.i64("console.event_backlog_len")?;
    let events_trimmed = r.u64("console.events_trimmed")?;
    let n = r.count("console.shards")?;
    let mut shards = Vec::with_capacity(n);
    for _ in 0..n {
        shards.push((
            r.i64("console.shard.queue_depth")?,
            r.i64("console.shard.queue_high_water")?,
            r.i64("console.shard.deferred")?,
        ));
    }
    Ok(ConsoleMetrics {
        windows_closed,
        windows_published,
        checkpoints_written,
        checkpoint_age_ms,
        event_backlog_len,
        events_trimmed,
        shards,
    })
}

fn put_table(out: &mut Vec<u8>, t: &Table) {
    put_str(out, &t.title);
    put_u32(out, t.headers.len() as u32);
    for h in &t.headers {
        put_str(out, h);
    }
    put_u32(out, t.rows.len() as u32);
    for row in &t.rows {
        put_u32(out, row.len() as u32);
        for cell in row {
            put_str(out, cell);
        }
    }
}

fn get_table(r: &mut Reader<'_>) -> Result<Table, ProtoError> {
    let title = r.string("table.title")?;
    let n = r.count("table.headers")?;
    let mut headers = Vec::with_capacity(n);
    for _ in 0..n {
        headers.push(r.string("table.header")?);
    }
    let n = r.count("table.rows")?;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let m = r.count("table.row")?;
        let mut row = Vec::with_capacity(m);
        for _ in 0..m {
            row.push(r.string("table.cell")?);
        }
        rows.push(row);
    }
    Ok(Table { title, headers, rows })
}

fn put_event(out: &mut Vec<u8>, ev: &ServiceEvent) {
    match ev {
        ServiceEvent::NodeIdentified { node_id, t0, identity } => {
            put_u8(out, 0);
            put_u64(out, *node_id as u64);
            put_f64(out, *t0);
            put_identity(out, identity);
        }
        ServiceEvent::EpochDetected { node_id, t0 } => {
            put_u8(out, 1);
            put_u64(out, *node_id as u64);
            put_f64(out, *t0);
        }
        ServiceEvent::Recalibrated { node_id, t0 } => {
            put_u8(out, 2);
            put_u64(out, *node_id as u64);
            put_f64(out, *t0);
        }
        ServiceEvent::DriftSuspected { node_id, t } => {
            put_u8(out, 3);
            put_u64(out, *node_id as u64);
            put_f64(out, *t);
        }
        ServiceEvent::WindowClosed { index, t0, t1 } => {
            put_u8(out, 4);
            put_u64(out, *index as u64);
            put_f64(out, *t0);
            put_f64(out, *t1);
        }
        ServiceEvent::CheckpointWritten { seq, windows_closed } => {
            put_u8(out, 5);
            put_u64(out, *seq);
            put_u64(out, *windows_closed as u64);
        }
        ServiceEvent::NodeComplete { node_id } => {
            put_u8(out, 6);
            put_u64(out, *node_id as u64);
        }
        ServiceEvent::ServiceComplete => put_u8(out, 7),
        ServiceEvent::Lagged { missed } => {
            put_u8(out, 8);
            put_u64(out, *missed);
        }
    }
}

fn get_event(r: &mut Reader<'_>) -> Result<ServiceEvent, ProtoError> {
    Ok(match r.u8("event.tag")? {
        0 => ServiceEvent::NodeIdentified {
            node_id: r.u64("event.node_id")? as usize,
            t0: r.f64("event.t0")?,
            identity: get_identity(r)?,
        },
        1 => ServiceEvent::EpochDetected {
            node_id: r.u64("event.node_id")? as usize,
            t0: r.f64("event.t0")?,
        },
        2 => ServiceEvent::Recalibrated {
            node_id: r.u64("event.node_id")? as usize,
            t0: r.f64("event.t0")?,
        },
        3 => ServiceEvent::DriftSuspected {
            node_id: r.u64("event.node_id")? as usize,
            t: r.f64("event.t")?,
        },
        4 => ServiceEvent::WindowClosed {
            index: r.u64("event.index")? as usize,
            t0: r.f64("event.t0")?,
            t1: r.f64("event.t1")?,
        },
        5 => ServiceEvent::CheckpointWritten {
            seq: r.u64("event.seq")?,
            windows_closed: r.u64("event.windows_closed")? as usize,
        },
        6 => ServiceEvent::NodeComplete { node_id: r.u64("event.node_id")? as usize },
        7 => ServiceEvent::ServiceComplete,
        8 => ServiceEvent::Lagged { missed: r.u64("event.missed")? },
        _ => return r.err("event.tag"),
    })
}

// ------------------------------------------------------------- requests

/// A client→collector request. One request per frame; the collector
/// answers with exactly one [`Response`] frame, except `Subscribe`, which
/// switches the connection into a stream of `Event` frames terminated by
/// `EndOfEvents`.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Identify the collector: fingerprint handshake.
    Hello,
    /// The full fleet state as `.gpck` interchange bytes.
    Snapshot,
    /// Fleet energy over `[t0, t1]` (whole-bucket clamped, the
    /// shard-fold-cache path).
    FleetEnergy {
        /// Range start, stream seconds.
        t0: f64,
        /// Range end, stream seconds.
        t1: f64,
    },
    /// The per-window aggregate table.
    WindowTable,
    /// The top-`k` misestimated-node table.
    TopMisestimated {
        /// How many nodes to rank.
        k: usize,
    },
    /// Stream events starting at emission sequence `from_seq`. A
    /// `from_seq` below the backlog's trimmed base yields one
    /// `Lagged` event covering the gap — the in-process semantics,
    /// end-to-end.
    Subscribe {
        /// First emission sequence to deliver.
        from_seq: u64,
    },
    /// Steer the collector ([`ControlMsg`]): recalibrate, checkpoint,
    /// shutdown.
    Control(ControlMsg),
    /// The raw current checkpoint (`.gpck` bytes), for archival or
    /// out-of-band restore.
    FetchCheckpoint,
    /// Ingest progress + console gauges (what `repro watch` renders).
    Progress,
}

impl Request {
    /// Encode into one frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Request::Hello => put_u8(&mut out, 0),
            Request::Snapshot => put_u8(&mut out, 1),
            Request::FleetEnergy { t0, t1 } => {
                put_u8(&mut out, 2);
                put_f64(&mut out, *t0);
                put_f64(&mut out, *t1);
            }
            Request::WindowTable => put_u8(&mut out, 3),
            Request::TopMisestimated { k } => {
                put_u8(&mut out, 4);
                put_u64(&mut out, *k as u64);
            }
            Request::Subscribe { from_seq } => {
                put_u8(&mut out, 5);
                put_u64(&mut out, *from_seq);
            }
            Request::Control(msg) => {
                put_u8(&mut out, 6);
                match msg {
                    ControlMsg::Recalibrate { node } => {
                        put_u8(&mut out, 0);
                        put_u64(&mut out, *node as u64);
                    }
                    ControlMsg::Checkpoint => put_u8(&mut out, 1),
                    ControlMsg::Shutdown => put_u8(&mut out, 2),
                }
            }
            Request::FetchCheckpoint => put_u8(&mut out, 7),
            Request::Progress => put_u8(&mut out, 8),
        }
        out
    }

    /// Decode one frame payload.
    pub fn decode(payload: &[u8]) -> Result<Request, ProtoError> {
        let mut r = Reader::new(payload);
        let req = match r.u8("request.tag")? {
            0 => Request::Hello,
            1 => Request::Snapshot,
            2 => Request::FleetEnergy { t0: r.f64("request.t0")?, t1: r.f64("request.t1")? },
            3 => Request::WindowTable,
            4 => Request::TopMisestimated { k: r.u64("request.k")? as usize },
            5 => Request::Subscribe { from_seq: r.u64("request.from_seq")? },
            6 => Request::Control(match r.u8("control.tag")? {
                0 => ControlMsg::Recalibrate { node: r.u64("control.node")? as usize },
                1 => ControlMsg::Checkpoint,
                2 => ControlMsg::Shutdown,
                _ => return r.err("control.tag"),
            }),
            7 => Request::FetchCheckpoint,
            8 => Request::Progress,
            _ => return r.err("request.tag"),
        };
        r.finish()?;
        Ok(req)
    }
}

// ------------------------------------------------------------ responses

/// The fingerprint handshake: who the collector is and whether its
/// service has drained.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HelloInfo {
    /// The collector's geometry/source fingerprint — the identity the
    /// federation pins and re-validates on every reconnect.
    pub fingerprint: ServiceFingerprint,
    /// Whether the underlying service has drained to completion.
    pub done: bool,
}

/// Ingest progress + console gauges, enough for a remote `repro watch`
/// frame to render byte-identically to a local one.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgressPayload {
    /// Producer-side ingest counters.
    pub stats: IngestStats,
    /// The instrument values the console panes print.
    pub console: ConsoleMetrics,
    /// Fleet size (denominator of the status line).
    pub n_total: usize,
    /// Whether the service has drained.
    pub done: bool,
}

/// A collector→client response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Answer to [`Request::Hello`].
    Hello(HelloInfo),
    /// Answer to [`Request::Snapshot`]: `.gpck` bytes plus the live-view
    /// counters a checkpoint does not carry.
    Snapshot {
        /// The encoded [`Checkpoint`] (validated, fingerprinted,
        /// checksummed by the persist layer).
        gpck: Vec<u8>,
        /// Windows covered by a published checkpoint file.
        windows_published: u64,
        /// Consumer-side ingest counters at snapshot time.
        stats: IngestStats,
    },
    /// Answer to [`Request::FleetEnergy`].
    FleetEnergy(crate::telemetry::accounting::FleetEnergy),
    /// Answer to the table requests (window table, top-misestimated).
    Table(Table),
    /// One subscribed event. `next_seq` is the cursor *after* this event:
    /// resuming with `Subscribe` at `from_seq = next_seq` continues the
    /// stream without loss or duplication.
    Event {
        /// Resume cursor after this event.
        next_seq: u64,
        /// The event itself (including synthesised `Lagged` markers).
        event: ServiceEvent,
    },
    /// The subscribed stream is exhausted: the service completed and the
    /// backlog is fully consumed. The connection returns to
    /// request/response mode.
    EndOfEvents,
    /// Answer to [`Request::Control`].
    Ack {
        /// Whether the control command was accepted.
        accepted: bool,
    },
    /// Answer to [`Request::FetchCheckpoint`].
    Checkpoint {
        /// The encoded [`Checkpoint`].
        gpck: Vec<u8>,
    },
    /// Answer to [`Request::Progress`].
    Progress(ProgressPayload),
    /// The request could not be served.
    Error {
        /// Human-readable reason.
        message: String,
    },
}

impl Response {
    /// Encode into one frame payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Response::Hello(info) => {
                put_u8(&mut out, 0);
                put_fingerprint(&mut out, &info.fingerprint);
                put_u8(&mut out, info.done as u8);
            }
            Response::Snapshot { gpck, windows_published, stats } => {
                put_u8(&mut out, 1);
                put_u64(&mut out, *windows_published);
                put_stats(&mut out, stats);
                put_bytes(&mut out, gpck);
            }
            Response::FleetEnergy(e) => {
                put_u8(&mut out, 2);
                put_f64(&mut out, e.t0);
                put_f64(&mut out, e.t1);
                put_f64(&mut out, e.naive_j);
                put_f64(&mut out, e.corrected_j);
                put_f64(&mut out, e.bound_j);
                put_f64(&mut out, e.truth_j);
            }
            Response::Table(t) => {
                put_u8(&mut out, 3);
                put_table(&mut out, t);
            }
            Response::Event { next_seq, event } => {
                put_u8(&mut out, 4);
                put_u64(&mut out, *next_seq);
                put_event(&mut out, event);
            }
            Response::EndOfEvents => put_u8(&mut out, 5),
            Response::Ack { accepted } => {
                put_u8(&mut out, 6);
                put_u8(&mut out, *accepted as u8);
            }
            Response::Checkpoint { gpck } => {
                put_u8(&mut out, 7);
                put_bytes(&mut out, gpck);
            }
            Response::Progress(p) => {
                put_u8(&mut out, 8);
                put_stats(&mut out, &p.stats);
                put_console(&mut out, &p.console);
                put_u64(&mut out, p.n_total as u64);
                put_u8(&mut out, p.done as u8);
            }
            Response::Error { message } => {
                put_u8(&mut out, 9);
                put_str(&mut out, message);
            }
        }
        out
    }

    /// Decode one frame payload.
    pub fn decode(payload: &[u8]) -> Result<Response, ProtoError> {
        let mut r = Reader::new(payload);
        let resp = match r.u8("response.tag")? {
            0 => Response::Hello(HelloInfo {
                fingerprint: get_fingerprint(&mut r)?,
                done: r.u8("hello.done")? != 0,
            }),
            1 => {
                let windows_published = r.u64("snapshot.windows_published")?;
                let stats = get_stats(&mut r)?;
                let gpck = r.bytes("snapshot.gpck")?.to_vec();
                Response::Snapshot { gpck, windows_published, stats }
            }
            2 => Response::FleetEnergy(crate::telemetry::accounting::FleetEnergy {
                t0: r.f64("energy.t0")?,
                t1: r.f64("energy.t1")?,
                naive_j: r.f64("energy.naive_j")?,
                corrected_j: r.f64("energy.corrected_j")?,
                bound_j: r.f64("energy.bound_j")?,
                truth_j: r.f64("energy.truth_j")?,
            }),
            3 => Response::Table(get_table(&mut r)?),
            4 => Response::Event {
                next_seq: r.u64("event.next_seq")?,
                event: get_event(&mut r)?,
            },
            5 => Response::EndOfEvents,
            6 => Response::Ack { accepted: r.u8("ack.accepted")? != 0 },
            7 => Response::Checkpoint { gpck: r.bytes("checkpoint.gpck")?.to_vec() },
            8 => {
                let stats = get_stats(&mut r)?;
                let console = get_console(&mut r)?;
                let n_total = r.u64("progress.n_total")? as usize;
                let done = r.u8("progress.done")? != 0;
                Response::Progress(ProgressPayload { stats, console, n_total, done })
            }
            9 => Response::Error { message: r.string("error.message")? },
            _ => return r.err("response.tag"),
        };
        r.finish()?;
        Ok(resp)
    }
}

// ------------------------------------- checkpoint → snapshot reconstruction

/// Expand a decoded checkpoint's nodes into query-side accounts +
/// registry entries — the exact per-node recipe a checkpoint *restore*
/// uses, so a finished node's account is bit-for-bit the account the
/// collector itself folds. In-flight nodes surface their frozen prefix
/// (unfrozen buckets zero, `complete == false`): the remote view is the
/// durable view, which converges to the live view once the stream drains.
pub fn node_views(ck: &Checkpoint, spec: BucketSpec) -> (Vec<NodeAccount>, Vec<NodeIdentity>) {
    let mut accounts = Vec::with_capacity(ck.nodes.len());
    let mut entries = Vec::with_capacity(ck.nodes.len());
    for node in &ck.nodes {
        let model = persist::static_model_name(&node.model);
        let identity = node.last_identity().unwrap_or_else(SensorIdentity::unsupported);
        let epochs: Vec<EpochIdentity> = node
            .epochs
            .iter()
            .filter_map(|e| e.identity.map(|identity| EpochIdentity { t0: e.t0, identity }))
            .collect();
        let complete = node.stage == NodeStage::Complete;
        let pad = |v: &[f64]| {
            let mut out = v.to_vec();
            out.resize(spec.n, 0.0);
            out
        };
        accounts.push(NodeAccount {
            node_id: node.node_id,
            model,
            generation: node.generation,
            identity,
            spec,
            naive_j: pad(&node.frozen.naive_j),
            corrected_j: pad(&node.frozen.corrected_j),
            bound_j: pad(&node.frozen.bound_j),
            truth_j: node.truth_j.clone().unwrap_or_else(|| vec![0.0; spec.n]),
            readings: node.readings,
            complete,
            frozen_n: if complete { spec.n } else { node.frozen.frozen_n },
        });
        entries.push(NodeIdentity {
            node_id: node.node_id,
            model,
            generation: node.generation,
            identity,
            epochs,
        });
    }
    (accounts, entries)
}

/// Reconstruct a [`TelemetrySnapshot`] from `.gpck` interchange plus the
/// live-view counters the Snapshot response carries alongside it. For a
/// drained service this is bit-for-bit the snapshot the collector holds
/// in-process (same accounts, same node-id fold order via
/// [`FleetAccounts::merge`], same registry) — the property the remote
/// console and the federation acceptance tests pin.
pub fn snapshot_from_checkpoint(
    ck: &Checkpoint,
    windows_published: usize,
    stats: IngestStats,
    schedule: ProbeSchedule,
) -> TelemetrySnapshot {
    let fp = &ck.fingerprint;
    let spec = BucketSpec { t0: 0.0, bucket_s: fp.bucket_s, n: fp.spec_n };
    let (accounts, entries) = node_views(ck, spec);
    let mut registry = Registry { entries };
    registry.finalize();
    TelemetrySnapshot {
        duration_s: fp.duration_s,
        window_s: fp.window_s,
        schedule,
        accounts: FleetAccounts::merge(spec, accounts),
        registry,
        stats,
        windows_closed: ck.windows_closed,
        windows_published,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_fingerprint() -> ServiceFingerprint {
        ServiceFingerprint {
            seed: 2024,
            n_total: 3,
            windows: 2,
            spec_n: 20,
            duration_s: 40.0,
            window_s: 20.0,
            bucket_s: 2.0,
            poll_period_s: 0.1,
            source_kind: SourceKind::Replay,
            source_digest: 0xDEAD_BEEF,
            fleet_digest: 0,
        }
    }

    #[test]
    fn every_request_roundtrips() {
        let all = vec![
            Request::Hello,
            Request::Snapshot,
            Request::FleetEnergy { t0: 0.25, t1: 39.75 },
            Request::WindowTable,
            Request::TopMisestimated { k: 10 },
            Request::Subscribe { from_seq: 77 },
            Request::Control(ControlMsg::Recalibrate { node: 5 }),
            Request::Control(ControlMsg::Checkpoint),
            Request::Control(ControlMsg::Shutdown),
            Request::FetchCheckpoint,
            Request::Progress,
        ];
        for req in all {
            assert_eq!(Request::decode(&req.encode()).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn every_response_roundtrips() {
        let identity = SensorIdentity {
            class: crate::telemetry::registry::SensorClass::Boxcar,
            update_s: Some(0.1),
            window_s: Some(0.025),
            smi_rise_s: None,
        };
        let all = vec![
            Response::Hello(HelloInfo { fingerprint: sample_fingerprint(), done: true }),
            Response::Snapshot {
                gpck: vec![1, 2, 3, 4],
                windows_published: 2,
                stats: IngestStats {
                    nodes: 3,
                    batches: 9,
                    readings: 1200,
                    recalibrations: 1,
                    drift_suspected: 0,
                },
            },
            Response::FleetEnergy(crate::telemetry::accounting::FleetEnergy {
                t0: 0.0,
                t1: 40.0,
                naive_j: 1.5,
                corrected_j: 2.5,
                bound_j: 0.25,
                truth_j: 2.75,
            }),
            Response::Table(Table {
                title: "fleet".into(),
                headers: vec!["a".into(), "b".into()],
                rows: vec![vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
            }),
            Response::Event {
                next_seq: 8,
                event: ServiceEvent::NodeIdentified { node_id: 2, t0: 0.0, identity },
            },
            Response::Event { next_seq: 9, event: ServiceEvent::Lagged { missed: 41 } },
            Response::EndOfEvents,
            Response::Ack { accepted: false },
            Response::Checkpoint { gpck: b"GPCK 1\n".to_vec() },
            Response::Progress(ProgressPayload {
                stats: IngestStats::default(),
                console: ConsoleMetrics {
                    windows_closed: 2,
                    windows_published: 1,
                    checkpoints_written: 3,
                    checkpoint_age_ms: -1,
                    event_backlog_len: 17,
                    events_trimmed: 0,
                    shards: vec![(0, 12, 0), (0, 9, 3)],
                },
                n_total: 4,
                done: false,
            }),
            Response::Error { message: "no such node".into() },
        ];
        for resp in all {
            assert_eq!(Response::decode(&resp.encode()).unwrap(), resp, "{resp:?}");
        }
    }

    #[test]
    fn trailing_bytes_are_rejected_with_offset() {
        let mut payload = Request::Hello.encode();
        payload.push(0xFF);
        let err = Request::decode(&payload).unwrap_err();
        assert_eq!(err.offset, 1);
    }

    #[test]
    fn truncated_payloads_carry_the_stop_offset() {
        let full = Response::Hello(HelloInfo { fingerprint: sample_fingerprint(), done: false })
            .encode();
        for cut in 0..full.len() {
            let err = Response::decode(&full[..cut]).unwrap_err();
            assert!(err.offset <= cut, "offset {} past cut {cut}", err.offset);
        }
    }
}
