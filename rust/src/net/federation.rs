//! Multi-collector federation: N served collectors, one fleet account.
//!
//! `repro federate` polls each upstream collector for its checkpoint
//! interchange bytes, validates fingerprints, remaps node ids into
//! disjoint per-collector ranges (upstream `i` owns
//! `[base_i, base_i + n_total_i)`, bases assigned by prefix sums in the
//! `--upstream` order), and folds the per-node payloads in global
//! node-id order — the same fold discipline
//! [`FleetAccounts::merge`] imposes on the sharded in-process service.
//! That shared discipline is the determinism claim: the federated
//! snapshot over collectors A and B is bit-for-bit the snapshot one
//! in-process service would produce over the union fleet, regardless of
//! upstream poll order (pinned by `tests/net.rs`).
//!
//! Failure semantics: a poll that fails (dead upstream, fingerprint
//! mismatch after a restart-as-something-else) never poisons the
//! aggregate — the federation keeps that upstream's last good view and
//! reports the degradation per-collector (stale-age column in
//! [`Federation::status_table`], staleness gauge in the metrics
//! registry). A killed-then-restarted upstream whose fingerprint still
//! matches re-joins transparently on the next poll.

use std::sync::Arc;
use std::time::Instant;

use crate::net::client::{NetConfig, NetError, RemoteCollector};
use crate::net::proto;
use crate::obs::metrics::{Counter, Gauge, MetricsRegistry};
use crate::report::Table;
use crate::telemetry::accounting::{BucketSpec, FleetAccounts, FleetEnergy};
use crate::telemetry::ingest::IngestStats;
use crate::telemetry::persist::{Checkpoint, ServiceFingerprint};
use crate::telemetry::registry::{ProbeSchedule, Registry};
use crate::telemetry::TelemetrySnapshot;

/// The last state successfully fetched from one upstream.
struct UpstreamView {
    ck: Checkpoint,
    windows_published: u64,
    stats: IngestStats,
    done: bool,
}

struct Upstream {
    collector: RemoteCollector,
    /// Global node-id offset: this upstream's node `k` is federated node
    /// `base + k`.
    base: usize,
    n_total: usize,
    view: Option<UpstreamView>,
    fetched_at: Option<Instant>,
    last_error: Option<String>,
    stale_ms: Arc<Gauge>,
    polls: Arc<Counter>,
    poll_errors: Arc<Counter>,
}

/// One row of [`Federation::status`]: how healthy an upstream is.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UpstreamStatus {
    /// The upstream's address, as given on the command line.
    pub addr: String,
    /// First global node id assigned to this upstream.
    pub base: usize,
    /// How many nodes the upstream owns.
    pub nodes: usize,
    /// Whether the most recent poll succeeded.
    pub ok: bool,
    /// Whether the upstream's service has completed.
    pub done: bool,
    /// Milliseconds since the last successful fetch, or -1 if none yet.
    pub stale_ms: i64,
    /// The most recent poll error, if the last poll failed.
    pub error: Option<String>,
}

/// A federated view over N serving collectors.
pub struct Federation {
    upstreams: Vec<Upstream>,
    spec: BucketSpec,
    duration_s: f64,
    window_s: f64,
    windows: usize,
    metrics: MetricsRegistry,
}

impl Federation {
    /// Connect to every upstream, run the fingerprint handshakes, check
    /// that all collectors share the same accounting geometry (bucket
    /// grid, window layout, run duration — bit-exact), and assign the
    /// disjoint node-id ranges. Fails if any upstream is unreachable: the
    /// id ranges are positional in `addrs`, so a federation must see its
    /// full roster once before it can tolerate outages.
    pub fn connect(addrs: &[String], cfg: NetConfig) -> Result<Federation, NetError> {
        if addrs.is_empty() {
            return Err(NetError::Io("federation needs at least one --upstream".into()));
        }
        let metrics = MetricsRegistry::default();
        let mut upstreams = Vec::with_capacity(addrs.len());
        let mut base = 0usize;
        let mut geometry: Option<ServiceFingerprint> = None;
        for addr in addrs {
            let collector = RemoteCollector::with_config(addr, cfg)?;
            let fp = collector.fingerprint().expect("handshake pins a fingerprint");
            match geometry {
                None => geometry = Some(fp),
                Some(g) => {
                    let same = g.spec_n == fp.spec_n
                        && g.windows == fp.windows
                        && g.bucket_s.to_bits() == fp.bucket_s.to_bits()
                        && g.window_s.to_bits() == fp.window_s.to_bits()
                        && g.duration_s.to_bits() == fp.duration_s.to_bits();
                    if !same {
                        return Err(NetError::Protocol(format!(
                            "upstream {addr} disagrees on accounting geometry \
                             (bucket/window/duration); a federation must fold \
                             identical grids"
                        )));
                    }
                }
            }
            let labels = [("upstream", addr.to_string())];
            upstreams.push(Upstream {
                collector,
                base,
                n_total: fp.n_total,
                view: None,
                fetched_at: None,
                last_error: None,
                stale_ms: metrics.gauge(
                    "telemetry_federation_upstream_stale_ms",
                    "Milliseconds since the last successful fetch from this upstream (-1 before the first).",
                    &labels,
                ),
                polls: metrics.counter(
                    "telemetry_federation_polls_total",
                    "Poll attempts against this upstream.",
                    &labels,
                ),
                poll_errors: metrics.counter(
                    "telemetry_federation_poll_errors_total",
                    "Failed polls against this upstream (kept serving the last good view).",
                    &labels,
                ),
            });
            base += fp.n_total;
        }
        let g = geometry.expect("at least one upstream");
        let federation = Federation {
            upstreams,
            spec: BucketSpec { t0: 0.0, bucket_s: g.bucket_s, n: g.spec_n },
            duration_s: g.duration_s,
            window_s: g.window_s,
            windows: g.windows,
            metrics,
        };
        Ok(federation)
    }

    /// Total nodes across the federation.
    pub fn n_total(&self) -> usize {
        self.upstreams.iter().map(|u| u.n_total).sum()
    }

    /// Windows per service run (shared geometry).
    pub fn windows(&self) -> usize {
        self.windows
    }

    /// The federation's metrics registry (per-upstream staleness gauge,
    /// poll counters) — hand it to an exporter for `--metrics-out`.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Poll every upstream once. Each poll re-runs the fingerprint
    /// handshake (so a restarted-as-something-else upstream is rejected,
    /// while a same-fingerprint restart re-joins) and then fetches the
    /// checkpoint interchange bytes. Failures keep the last good view.
    /// Returns how many upstreams refreshed.
    pub fn poll(&mut self) -> usize {
        let mut refreshed = 0;
        for u in &mut self.upstreams {
            u.polls.inc();
            let fetched = u.collector.hello().and_then(|info| {
                let (ck, windows_published, stats) = u.collector.raw_snapshot()?;
                Ok(UpstreamView { ck, windows_published, stats, done: info.done })
            });
            match fetched {
                Ok(view) => {
                    u.view = Some(view);
                    u.fetched_at = Some(Instant::now());
                    u.last_error = None;
                    refreshed += 1;
                }
                Err(e) => {
                    u.poll_errors.inc();
                    u.last_error = Some(e.to_string());
                }
            }
            u.stale_ms.set(match u.fetched_at {
                Some(t) => t.elapsed().as_millis() as i64,
                None => -1,
            });
        }
        refreshed
    }

    /// Whether every upstream's service has completed (as of its last
    /// good view).
    pub fn all_done(&self) -> bool {
        self.upstreams.iter().all(|u| u.view.as_ref().is_some_and(|v| v.done))
    }

    /// Per-upstream health.
    pub fn status(&self) -> Vec<UpstreamStatus> {
        self.upstreams
            .iter()
            .map(|u| UpstreamStatus {
                addr: u.collector.addr().to_string(),
                base: u.base,
                nodes: u.n_total,
                ok: u.last_error.is_none() && u.view.is_some(),
                done: u.view.as_ref().is_some_and(|v| v.done),
                stale_ms: match u.fetched_at {
                    Some(t) => t.elapsed().as_millis() as i64,
                    None => -1,
                },
                error: u.last_error.clone(),
            })
            .collect()
    }

    /// The health table `repro federate` prints.
    pub fn status_table(&self) -> Table {
        let mut t = Table::new(
            "federation upstreams",
            &["upstream", "nodes", "node ids", "state", "stale", "last error"],
        );
        for s in self.status() {
            let state = if !s.ok {
                "degraded"
            } else if s.done {
                "done"
            } else {
                "running"
            };
            let stale = if s.stale_ms < 0 {
                "never".to_string()
            } else {
                format!("{:.1}s", crate::units::ms_to_s(s.stale_ms as f64))
            };
            t.row(&[
                s.addr,
                s.nodes.to_string(),
                format!("{}..{}", s.base, s.base + s.nodes),
                state.to_string(),
                stale,
                s.error.unwrap_or_else(|| "-".to_string()),
            ]);
        }
        t
    }

    /// Fold the last good views into one federated snapshot: per-node
    /// accounts and identities from every upstream, node ids remapped
    /// into this federation's disjoint ranges, merged in ascending global
    /// node-id order. Fails until every upstream has produced at least
    /// one good view (a partial roster would silently misreport the
    /// fleet).
    pub fn snapshot(&self) -> Result<TelemetrySnapshot, NetError> {
        let mut accounts = Vec::with_capacity(self.n_total());
        let mut entries = Vec::with_capacity(self.n_total());
        let mut stats = IngestStats::default();
        let mut windows_closed = usize::MAX;
        let mut windows_published = usize::MAX;
        for u in &self.upstreams {
            let view = u.view.as_ref().ok_or_else(|| {
                NetError::Io(format!(
                    "upstream {} has no successful fetch yet; federated account \
                     would omit its {} node(s)",
                    u.collector.addr(),
                    u.n_total
                ))
            })?;
            let (mut accs, mut ids) = proto::node_views(&view.ck, self.spec);
            for a in &mut accs {
                a.node_id += u.base;
            }
            for id in &mut ids {
                id.node_id += u.base;
            }
            accounts.extend(accs);
            entries.extend(ids);
            stats.nodes += view.stats.nodes;
            stats.batches += view.stats.batches;
            stats.readings += view.stats.readings;
            stats.recalibrations += view.stats.recalibrations;
            stats.drift_suspected += view.stats.drift_suspected;
            windows_closed = windows_closed.min(view.ck.windows_closed);
            windows_published = windows_published.min(view.windows_published as usize);
        }
        let mut registry = Registry { entries };
        registry.finalize();
        Ok(TelemetrySnapshot {
            duration_s: self.duration_s,
            window_s: self.window_s,
            schedule: ProbeSchedule::default(),
            accounts: FleetAccounts::merge(self.spec, accounts),
            registry,
            stats,
            windows_closed,
            windows_published,
        })
    }

    /// Federated fleet energy over `[t0, t1]`.
    pub fn fleet_energy(&self, t0: f64, t1: f64) -> Result<FleetEnergy, NetError> {
        Ok(self.snapshot()?.fleet_energy(t0, t1))
    }
}
