//! Network query/control plane + multi-collector federation.
//!
//! The paper's fleet-scale claim — nvidia-smi's ~25% attention mis-states
//! energy "especially when considering data centres housing tens of
//! thousands of GPUs" (§1) — only bites when one accounting core is *not*
//! enough. This module turns the in-process
//! [`ServiceHandle`](crate::telemetry::ServiceHandle) into a wire-reachable
//! collector and a set of collectors into one federated fleet account,
//! with zero external dependencies (std::net only — in the spirit of the
//! hand-rolled `.gpck` checkpoint format):
//!
//! - [`frame`] — versioned, length-prefixed, FNV-1a-checksummed binary
//!   frames (the transport grammar; property-tested to never panic on
//!   adversarial bytes).
//! - [`proto`] — the request/response message codec layered on frames.
//!   `.gpck` checkpoint bytes are the fleet-state interchange unit:
//!   [`persist`](crate::telemetry::persist) already fingerprints the
//!   fleet/config/source, so a snapshot travels as the same durable record
//!   a restore would consume.
//! - [`server`] — `repro serve`: a [`TcpListener`](std::net::TcpListener)
//!   accept loop + per-client threads wrapping a live service handle.
//!   Queries ride the existing shard-fold-cache path; `Subscribe` bridges
//!   the event backlog cursor over the socket with the bounded-backlog
//!   `Lagged` semantics intact; slow or dead clients get write deadlines
//!   and a disconnect, never a stalled ingest.
//! - [`client`] — a blocking [`RemoteCollector`](client::RemoteCollector)
//!   with connect/read timeouts, exponential-backoff reconnect, and
//!   seq-based subscribe resume; powers `repro query` and
//!   `repro watch --connect`.
//! - [`federation`] — `repro federate`: polls N collectors, validates
//!   fingerprints (a killed-then-restarted upstream re-joins only if its
//!   fingerprint still matches), remaps node ids into disjoint
//!   per-collector ranges, and folds per-node payloads in global node-id
//!   order — the same fold discipline the sharded service uses — so the
//!   federated account is bit-for-bit the single-service account of the
//!   union fleet. Degraded upstreams are reported per-collector (stale-age
//!   column) instead of poisoning the aggregate.

#![warn(missing_docs)]

pub mod client;
pub mod federation;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::{NetConfig, NetError, RemoteCollector, RemoteEvents};
pub use federation::{Federation, UpstreamStatus};
pub use frame::{decode_frame, encode_frame, FrameError};
pub use proto::{snapshot_from_checkpoint, HelloInfo, ProgressPayload, Request, Response};
pub use server::NetServer;
