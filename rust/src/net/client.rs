//! `RemoteCollector` — the blocking client side of the network plane.
//!
//! One TCP connection per collector, request/response framed by
//! [`frame`](super::frame). The client owns the reliability policy:
//! connect and read timeouts, exponential-backoff reconnect (a dead
//! persistent connection is retried transparently once per call), and
//! seq-based subscribe resume — every `Event` frame carries the cursor to
//! resume from, so a dropped event stream reconnects with
//! `Subscribe { from_seq }` and loses nothing the bounded backlog still
//! holds (and observes the same `Lagged` gap marker an in-process
//! subscriber would when it does not).
//!
//! The first `Hello` pins the collector's fingerprint: every later
//! handshake and every snapshot is validated against it, so a collector
//! that restarts *with the same config/fleet/source* re-joins silently,
//! while one that comes back different is refused with
//! [`NetError::FingerprintMismatch`] instead of quietly corrupting the
//! account — the federation's re-join rule, enforced at the client layer.

use std::io::{self, Read};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::net::frame;
use crate::net::proto::{
    snapshot_from_checkpoint, HelloInfo, ProgressPayload, Request, Response,
};
use crate::report::Table;
use crate::telemetry::accounting::FleetEnergy;
use crate::telemetry::ingest::IngestStats;
use crate::telemetry::persist::{Checkpoint, ServiceFingerprint};
use crate::telemetry::registry::ProbeSchedule;
use crate::telemetry::service::{ControlMsg, ServiceEvent};
use crate::telemetry::TelemetrySnapshot;

/// Why a remote call failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetError {
    /// Transport failure (connect, read, write, or reconnect exhausted).
    Io(String),
    /// The peer spoke, but not the protocol (frame or message violation).
    Protocol(String),
    /// The collector answered with an `Error` response.
    Remote(String),
    /// The collector's fingerprint no longer matches the one pinned at
    /// first contact: it restarted with a different config/fleet/source.
    FingerprintMismatch {
        /// The collector's address.
        addr: String,
    },
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(e) => write!(f, "transport: {e}"),
            NetError::Protocol(e) => write!(f, "protocol: {e}"),
            NetError::Remote(e) => write!(f, "collector refused: {e}"),
            NetError::FingerprintMismatch { addr } => write!(
                f,
                "collector at {addr} restarted with a different fingerprint \
                 (config/fleet/source changed); refusing to mix accounts"
            ),
        }
    }
}

impl std::error::Error for NetError {}

/// Client reliability knobs. The defaults suit loopback and LAN
/// collectors; scripts can widen them for WAN hops.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// TCP connect timeout per address attempt.
    pub connect_timeout: Duration,
    /// How long one response may take before the call fails.
    pub read_timeout: Duration,
    /// First reconnect backoff step (doubles per attempt).
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Connect attempts per reconnect (with backoff between them).
    pub attempts: u32,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            connect_timeout: Duration::from_secs(2),
            read_timeout: Duration::from_secs(10),
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            attempts: 5,
        }
    }
}

/// A blocking client for one serving collector.
pub struct RemoteCollector {
    addr: String,
    cfg: NetConfig,
    stream: Option<TcpStream>,
    pinned: Option<ServiceFingerprint>,
}

impl RemoteCollector {
    /// Connect to `addr` (host:port) and run the fingerprint handshake.
    pub fn connect(addr: &str) -> Result<RemoteCollector, NetError> {
        RemoteCollector::with_config(addr, NetConfig::default())
    }

    /// [`connect`](RemoteCollector::connect) with explicit reliability
    /// knobs.
    pub fn with_config(addr: &str, cfg: NetConfig) -> Result<RemoteCollector, NetError> {
        let mut c =
            RemoteCollector { addr: addr.to_string(), cfg, stream: None, pinned: None };
        c.hello()?;
        Ok(c)
    }

    /// The collector's address, as given.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The fingerprint pinned at first contact.
    pub fn fingerprint(&self) -> Option<ServiceFingerprint> {
        self.pinned
    }

    fn dial(&self) -> Result<TcpStream, NetError> {
        let addrs: Vec<SocketAddr> = self
            .addr
            .to_socket_addrs()
            .map_err(|e| NetError::Io(format!("cannot resolve {}: {e}", self.addr)))?
            .collect();
        let mut last = NetError::Io(format!("{} resolves to no address", self.addr));
        for a in addrs {
            match TcpStream::connect_timeout(&a, self.cfg.connect_timeout) {
                Ok(s) => {
                    s.set_read_timeout(Some(self.cfg.read_timeout))
                        .map_err(|e| NetError::Io(e.to_string()))?;
                    s.set_write_timeout(Some(self.cfg.read_timeout))
                        .map_err(|e| NetError::Io(e.to_string()))?;
                    s.set_nodelay(true).ok();
                    return Ok(s);
                }
                Err(e) => last = NetError::Io(format!("connect {a}: {e}")),
            }
        }
        Err(last)
    }

    /// Make sure a live connection exists, reconnecting with exponential
    /// backoff when it does not.
    fn ensure(&mut self) -> Result<(), NetError> {
        if self.stream.is_some() {
            return Ok(());
        }
        let mut delay = self.cfg.backoff_base;
        let mut last = NetError::Io("no connect attempts configured".into());
        for attempt in 0..self.cfg.attempts.max(1) {
            if attempt > 0 {
                std::thread::sleep(delay);
                delay = (delay * 2).min(self.cfg.backoff_cap);
            }
            match self.dial() {
                Ok(s) => {
                    self.stream = Some(s);
                    return Ok(());
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// One request/response exchange, with one transparent reconnect: a
    /// persistent connection whose peer went away surfaces the death on
    /// the first write or read, so the call is retried once on a fresh
    /// connection before failing.
    fn call(&mut self, req: &Request) -> Result<Response, NetError> {
        for attempt in 0..2 {
            self.ensure()?;
            let stream = self.stream.as_mut().expect("ensured above");
            let exchange = (|| -> io::Result<Vec<u8>> {
                frame::write_frame(stream, &req.encode())?;
                frame::read_frame(stream)
            })();
            match exchange {
                Ok(payload) => {
                    let resp = Response::decode(&payload)
                        .map_err(|e| NetError::Protocol(e.to_string()))?;
                    if let Response::Error { message } = resp {
                        return Err(NetError::Remote(message));
                    }
                    return Ok(resp);
                }
                Err(e) => {
                    self.stream = None;
                    if attempt == 1 {
                        return Err(NetError::Io(e.to_string()));
                    }
                }
            }
        }
        unreachable!("two attempts above always return")
    }

    /// Fingerprint handshake. Pins on first success; later calls
    /// re-validate, which is how a federation detects an upstream that
    /// restarted as something else.
    pub fn hello(&mut self) -> Result<HelloInfo, NetError> {
        match self.call(&Request::Hello)? {
            Response::Hello(info) => match self.pinned {
                Some(fp) if fp != info.fingerprint => {
                    Err(NetError::FingerprintMismatch { addr: self.addr.clone() })
                }
                _ => {
                    self.pinned = Some(info.fingerprint);
                    Ok(info)
                }
            },
            other => Err(unexpected("Hello", &other)),
        }
    }

    /// The collector's fleet state as a validated, fingerprint-checked
    /// [`Checkpoint`], plus the live-view counters the interchange bytes
    /// do not carry.
    pub fn raw_snapshot(&mut self) -> Result<(Checkpoint, u64, IngestStats), NetError> {
        match self.call(&Request::Snapshot)? {
            Response::Snapshot { gpck, windows_published, stats } => {
                let ck = Checkpoint::decode(&gpck).map_err(NetError::Protocol)?;
                if let Some(fp) = self.pinned {
                    if ck.fingerprint != fp {
                        return Err(NetError::FingerprintMismatch { addr: self.addr.clone() });
                    }
                }
                Ok((ck, windows_published, stats))
            }
            other => Err(unexpected("Snapshot", &other)),
        }
    }

    /// The collector's state reconstructed as a [`TelemetrySnapshot`] —
    /// bit-for-bit the collector's own snapshot once its service drained
    /// (see [`snapshot_from_checkpoint`]).
    pub fn snapshot(&mut self) -> Result<TelemetrySnapshot, NetError> {
        let (ck, windows_published, stats) = self.raw_snapshot()?;
        Ok(snapshot_from_checkpoint(
            &ck,
            windows_published as usize,
            stats,
            ProbeSchedule::default(),
        ))
    }

    /// Fleet energy over `[t0, t1]`, served by the collector's
    /// shard-fold-cache path.
    pub fn fleet_energy(&mut self, t0: f64, t1: f64) -> Result<FleetEnergy, NetError> {
        match self.call(&Request::FleetEnergy { t0, t1 })? {
            Response::FleetEnergy(e) => Ok(e),
            other => Err(unexpected("FleetEnergy", &other)),
        }
    }

    /// The per-window aggregate table, rendered collector-side.
    pub fn window_table(&mut self) -> Result<Table, NetError> {
        match self.call(&Request::WindowTable)? {
            Response::Table(t) => Ok(t),
            other => Err(unexpected("WindowTable", &other)),
        }
    }

    /// The top-`k` misestimated-node table, rendered collector-side.
    pub fn top_misestimated(&mut self, k: usize) -> Result<Table, NetError> {
        match self.call(&Request::TopMisestimated { k })? {
            Response::Table(t) => Ok(t),
            other => Err(unexpected("TopMisestimated", &other)),
        }
    }

    /// Steer the collector; `Ok(false)` when the command was understood
    /// but not accepted (unknown node, no checkpoint sink).
    pub fn control(&mut self, msg: ControlMsg) -> Result<bool, NetError> {
        match self.call(&Request::Control(msg))? {
            Response::Ack { accepted } => Ok(accepted),
            other => Err(unexpected("Control", &other)),
        }
    }

    /// Fetch the raw current checkpoint.
    pub fn fetch_checkpoint(&mut self) -> Result<Checkpoint, NetError> {
        match self.call(&Request::FetchCheckpoint)? {
            Response::Checkpoint { gpck } => {
                Checkpoint::decode(&gpck).map_err(NetError::Protocol)
            }
            other => Err(unexpected("FetchCheckpoint", &other)),
        }
    }

    /// Ingest progress + the console gauge values.
    pub fn progress(&mut self) -> Result<ProgressPayload, NetError> {
        match self.call(&Request::Progress)? {
            Response::Progress(p) => Ok(p),
            other => Err(unexpected("Progress", &other)),
        }
    }

    /// Switch the connection into event streaming from `from_seq`. The
    /// returned [`RemoteEvents`] yields `(next_seq, event)` pairs until
    /// the collector sends `EndOfEvents` (service complete, backlog
    /// drained), after which the connection is back in request mode.
    pub fn subscribe_from(&mut self, from_seq: u64) -> Result<RemoteEvents<'_>, NetError> {
        self.ensure()?;
        let stream = self.stream.as_mut().expect("ensured above");
        frame::write_frame(stream, &Request::Subscribe { from_seq }.encode())
            .map_err(|e| NetError::Io(e.to_string()))?;
        Ok(RemoteEvents { collector: self, next_seq: from_seq, finished: false })
    }

    /// Stream every event from `from_seq` to the end of the service into
    /// `f`, transparently reconnecting and resuming (seq-based) if the
    /// collector drops mid-stream. Returns the final resume cursor.
    pub fn drain_events(
        &mut self,
        from_seq: u64,
        mut f: impl FnMut(u64, ServiceEvent),
    ) -> Result<u64, NetError> {
        let mut seq = from_seq;
        loop {
            let mut events = self.subscribe_from(seq)?;
            let ended = loop {
                match events.next() {
                    Ok(Some((next_seq, event))) => {
                        seq = next_seq;
                        f(next_seq, event);
                    }
                    Ok(None) => break true,
                    Err(NetError::Io(_)) => break false,
                    Err(e) => return Err(e),
                }
            };
            if ended {
                return Ok(seq);
            }
            // dropped mid-stream: reconnect (backoff inside ensure) and
            // resume exactly where the last delivered event left off
        }
    }
}

/// The event-streaming mode of a [`RemoteCollector`] connection.
pub struct RemoteEvents<'a> {
    collector: &'a mut RemoteCollector,
    next_seq: u64,
    finished: bool,
}

impl RemoteEvents<'_> {
    /// The cursor to resume from if this stream is dropped.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Block for the next event. `Ok(None)` once the stream ended
    /// normally. An `Err(Io)` invalidates the connection; resume with
    /// [`RemoteCollector::subscribe_from`] at [`RemoteEvents::next_seq`].
    pub fn next(&mut self) -> Result<Option<(u64, ServiceEvent)>, NetError> {
        if self.finished {
            return Ok(None);
        }
        let payload = match self.read_event_frame() {
            Ok(p) => p,
            Err(e) => {
                self.collector.stream = None;
                return Err(NetError::Io(e.to_string()));
            }
        };
        match Response::decode(&payload).map_err(|e| NetError::Protocol(e.to_string()))? {
            Response::Event { next_seq, event } => {
                self.next_seq = next_seq;
                Ok(Some((next_seq, event)))
            }
            Response::EndOfEvents => {
                self.finished = true;
                Ok(None)
            }
            Response::Error { message } => Err(NetError::Remote(message)),
            other => Err(unexpected("Subscribe", &other)),
        }
    }

    /// Read one frame, waiting patiently while the socket is merely idle
    /// (events can be sparse): a read timeout with no bytes consumed is a
    /// quiet stream, not an error. Once a frame starts it must finish
    /// within the socket's read timeout per chunk.
    fn read_event_frame(&mut self) -> io::Result<Vec<u8>> {
        let stream =
            self.collector.stream.as_mut().ok_or_else(|| {
                io::Error::new(io::ErrorKind::NotConnected, "stream was invalidated")
            })?;
        let mut header = [0u8; frame::HEADER_LEN];
        let mut got = 0usize;
        while got < header.len() {
            match stream.read(&mut header[got..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "collector closed the event stream",
                    ))
                }
                Ok(n) => got += n,
                Err(e)
                    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
                        && got == 0 =>
                {
                    // idle stream: keep waiting for the next event
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        let len = frame::parse_header(&header)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?
            as usize;
        let mut buf = vec![0u8; frame::HEADER_LEN + len + frame::TRAILER_LEN];
        buf[..frame::HEADER_LEN].copy_from_slice(&header);
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut at = frame::HEADER_LEN;
        while at < buf.len() {
            match stream.read(&mut buf[at..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "collector closed mid-frame",
                    ))
                }
                Ok(n) => at += n,
                Err(e)
                    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
                {
                    if Instant::now() > deadline {
                        return Err(io::Error::new(io::ErrorKind::TimedOut, "frame stalled"));
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        match frame::decode_frame(&buf) {
            Ok((payload, _)) => Ok(payload.to_vec()),
            Err(e) => Err(io::Error::new(io::ErrorKind::InvalidData, e)),
        }
    }
}

fn unexpected(wanted: &str, got: &Response) -> NetError {
    let tag = match got {
        Response::Hello(_) => "Hello",
        Response::Snapshot { .. } => "Snapshot",
        Response::FleetEnergy(_) => "FleetEnergy",
        Response::Table(_) => "Table",
        Response::Event { .. } => "Event",
        Response::EndOfEvents => "EndOfEvents",
        Response::Ack { .. } => "Ack",
        Response::Checkpoint { .. } => "Checkpoint",
        Response::Progress(_) => "Progress",
        Response::Error { .. } => "Error",
    };
    NetError::Protocol(format!("expected a {wanted} response, got {tag}"))
}
