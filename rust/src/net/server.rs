//! `repro serve` — the collector's socket front-end.
//!
//! A [`TcpListener`] accept loop plus one thread per client wraps a live
//! [`ServiceHandle`]. The ingest path is never on this thread: queries go
//! through the handle's existing lock discipline (fleet energy through
//! the shard-fold-cache path, snapshots through the per-shard snapshot
//! cache), so a slow — or adversarial — client can at worst stall its own
//! connection:
//!
//! - **Framing violations disconnect.** Once a frame fails to parse the
//!   byte stream is unsynchronised, so the server sends one `Error`
//!   response (best-effort) and drops the connection. Malformed *message
//!   payloads* inside a valid frame keep the connection: framing is still
//!   in sync, so an `Error` response is returned and the next request is
//!   served.
//! - **Write deadlines.** Every response write carries a deadline
//!   ([`WRITE_DEADLINE`]); a client that stops draining its socket is
//!   disconnected rather than parked on.
//! - **Subscribe bridges the backlog cursor.** `Subscribe { from_seq }`
//!   turns the connection into an event stream driven by
//!   [`ServiceHandle::subscribe_from`]: the bounded-backlog `Lagged`
//!   semantics are preserved end-to-end (a subscriber that falls behind
//!   the backlog cap receives the same synthesised gap marker an
//!   in-process subscriber would), and the stream ends with `EndOfEvents`
//!   once the service completes and the backlog is drained, returning the
//!   connection to request/response mode.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::frame;
use crate::net::proto::{HelloInfo, ProgressPayload, Request, Response};
use crate::obs::console::ConsoleMetrics;
use crate::obs::metrics::NetMetrics;
use crate::telemetry::query;
use crate::telemetry::service::{ServiceEvent, ServiceHandle};

/// Poll granularity for idle reads and the accept loop: how quickly the
/// server notices a shutdown request.
const IDLE_POLL: Duration = Duration::from_millis(200);
/// How long a started frame may stall before its client is declared slow
/// and disconnected.
const FRAME_DEADLINE: Duration = Duration::from_secs(5);
/// How long a response write may block before its client is declared
/// dead and disconnected.
const WRITE_DEADLINE: Duration = Duration::from_secs(5);

/// A serving collector: the accept loop plus its client threads.
/// Dropping (or [`NetServer::shutdown`]) stops accepting, signals every
/// client thread, and joins them.
pub struct NetServer {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:7070`, port 0 for ephemeral) and
    /// start serving `handle`. Connection metrics are registered into the
    /// service's own metrics registry, so `--metrics-out` exporters
    /// surface the network plane automatically.
    pub fn bind(handle: Arc<ServiceHandle>, addr: &str) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(NetMetrics::register(&handle.metrics_handle().registry));
        let accept = {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || accept_loop(listener, handle, stop, metrics))
        };
        Ok(NetServer { local, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Stop accepting, disconnect clients, and join every thread.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    handle: Arc<ServiceHandle>,
    stop: Arc<AtomicBool>,
    metrics: Arc<NetMetrics>,
) {
    let mut clients: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let handle = Arc::clone(&handle);
                let stop = Arc::clone(&stop);
                let metrics = Arc::clone(&metrics);
                clients.push(std::thread::spawn(move || {
                    client_loop(stream, handle, stop, metrics)
                }));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(IDLE_POLL);
            }
            Err(_) => std::thread::sleep(IDLE_POLL),
        }
    }
    for c in clients {
        let _ = c.join();
    }
}

fn client_loop(
    mut stream: TcpStream,
    handle: Arc<ServiceHandle>,
    stop: Arc<AtomicBool>,
    metrics: Arc<NetMetrics>,
) {
    metrics.clients_connected.add(1);
    let _ = serve_client(&mut stream, &handle, &stop, &metrics);
    metrics.clients_connected.add(-1);
}

/// What a bounded-blocking read produced.
enum Fill {
    /// The buffer is full.
    Full,
    /// Nothing arrived within one poll (only when `idle_ok`).
    Idle,
    /// The peer closed the connection cleanly before the first byte.
    Closed,
    /// The server is shutting down.
    Stopped,
}

/// Fill `buf` from `stream` under the slow-client policy: with `idle_ok`,
/// a quiet socket returns [`Fill::Idle`] so the caller can re-check the
/// stop flag; once bytes start flowing the whole buffer must land within
/// [`FRAME_DEADLINE`] or the read fails (the disconnect).
fn fill(stream: &mut TcpStream, buf: &mut [u8], idle_ok: bool, stop: &AtomicBool) -> io::Result<Fill> {
    let mut got = 0usize;
    let mut deadline: Option<Instant> = None;
    while got < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Ok(Fill::Stopped);
        }
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && idle_ok {
                    return Ok(Fill::Closed);
                }
                return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer closed mid-frame"));
            }
            Ok(n) => {
                got += n;
                deadline = Some(Instant::now() + FRAME_DEADLINE);
            }
            Err(e)
                if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) =>
            {
                if got == 0 && idle_ok {
                    return Ok(Fill::Idle);
                }
                match deadline {
                    Some(d) if Instant::now() > d => {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "slow client: frame stalled past the deadline",
                        ))
                    }
                    Some(_) => {}
                    None => deadline = Some(Instant::now() + FRAME_DEADLINE),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Fill::Full)
}

fn reply(stream: &mut TcpStream, metrics: &NetMetrics, resp: &Response) -> io::Result<()> {
    let frame = frame::encode_frame(&resp.encode());
    stream.write_all(&frame)?;
    metrics.frames_out.inc();
    metrics.bytes_out.add(frame.len() as u64);
    Ok(())
}

fn serve_client(
    stream: &mut TcpStream,
    handle: &ServiceHandle,
    stop: &AtomicBool,
    metrics: &NetMetrics,
) -> io::Result<()> {
    stream.set_read_timeout(Some(IDLE_POLL))?;
    stream.set_write_timeout(Some(WRITE_DEADLINE))?;
    stream.set_nodelay(true).ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let mut header = [0u8; frame::HEADER_LEN];
        match fill(stream, &mut header, true, stop)? {
            Fill::Idle => continue,
            Fill::Closed | Fill::Stopped => return Ok(()),
            Fill::Full => {}
        }
        // Validate the header before allocating: an adversarial length
        // field is rejected here, and any framing violation ends the
        // connection — the byte stream is out of sync past this point.
        let len = match frame::parse_header(&header) {
            Ok(len) => len as usize,
            Err(e) => {
                metrics.frames_rejected.inc();
                let _ = reply(stream, metrics, &Response::Error { message: e.to_string() });
                return Ok(());
            }
        };
        let mut buf = vec![0u8; frame::HEADER_LEN + len + frame::TRAILER_LEN];
        buf[..frame::HEADER_LEN].copy_from_slice(&header);
        match fill(stream, &mut buf[frame::HEADER_LEN..], false, stop)? {
            Fill::Full => {}
            _ => return Ok(()),
        }
        let payload = match frame::decode_frame(&buf) {
            Ok((payload, _)) => payload.to_vec(),
            Err(e) => {
                metrics.frames_rejected.inc();
                let _ = reply(stream, metrics, &Response::Error { message: e.to_string() });
                return Ok(());
            }
        };
        metrics.frames_in.inc();
        metrics.bytes_in.add(buf.len() as u64);
        // A bad message inside a good frame keeps the connection: framing
        // is still synchronised, so answer with Error and keep serving.
        let req = match Request::decode(&payload) {
            Ok(req) => req,
            Err(e) => {
                metrics.frames_rejected.inc();
                reply(stream, metrics, &Response::Error { message: e.to_string() })?;
                continue;
            }
        };
        match req {
            Request::Subscribe { from_seq } => {
                stream_events(stream, handle, stop, metrics, from_seq)?
            }
            other => {
                let resp = answer(handle, other);
                reply(stream, metrics, &resp)?;
            }
        }
    }
}

/// Serve one request/response exchange. Total: every request variant
/// (Subscribe is handled by the caller) maps to exactly one response.
fn answer(handle: &ServiceHandle, req: Request) -> Response {
    match req {
        Request::Hello => Response::Hello(HelloInfo {
            fingerprint: handle.fingerprint(),
            done: handle.is_done(),
        }),
        Request::Snapshot => {
            // live-view counters from the snapshot, durable state as
            // `.gpck` interchange; after the drain the two views are the
            // same account bit-for-bit
            let snap = handle.snapshot();
            let ck = handle.checkpoint();
            Response::Snapshot {
                gpck: ck.encode(),
                windows_published: snap.windows_published as u64,
                stats: snap.stats,
            }
        }
        Request::FleetEnergy { t0, t1 } => Response::FleetEnergy(handle.fleet_energy(t0, t1)),
        Request::WindowTable => Response::Table(query::window_table(&handle.snapshot())),
        Request::TopMisestimated { k } => {
            Response::Table(query::top_misestimated(&handle.snapshot(), k))
        }
        Request::Control(msg) => Response::Ack { accepted: handle.control(msg) },
        Request::FetchCheckpoint => {
            Response::Checkpoint { gpck: handle.checkpoint().encode() }
        }
        Request::Progress => Response::Progress(ProgressPayload {
            stats: handle.progress(),
            console: ConsoleMetrics::from(handle.metrics_handle()),
            n_total: handle.fingerprint().n_total,
            done: handle.is_done(),
        }),
        Request::Subscribe { .. } => {
            Response::Error { message: "subscribe is a streaming request".into() }
        }
    }
}

/// Bridge the event backlog cursor over the socket until the service
/// completes (then `EndOfEvents`) or the client/server goes away. Each
/// frame carries the resume cursor, so a dropped subscriber reconnects
/// with `Subscribe { from_seq: last_next_seq }` and loses nothing the
/// backlog still holds — and observes a `Lagged` gap marker when it
/// does not, exactly like an in-process subscriber.
fn stream_events(
    stream: &mut TcpStream,
    handle: &ServiceHandle,
    stop: &AtomicBool,
    metrics: &NetMetrics,
    from_seq: u64,
) -> io::Result<()> {
    let events = handle.subscribe_from(from_seq);
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match events.recv_timeout(IDLE_POLL) {
            Ok(event) => {
                if let ServiceEvent::Lagged { missed } = event {
                    metrics.subscribe_lagged.add(missed);
                }
                reply(stream, metrics, &Response::Event { next_seq: events.next_seq(), event })?;
            }
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                reply(stream, metrics, &Response::EndOfEvents)?;
                return Ok(());
            }
        }
    }
}
