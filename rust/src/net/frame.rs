//! The wire frame: magic, protocol version, length prefix, payload,
//! FNV-1a trailer.
//!
//! Grammar (all integers little-endian):
//!
//! ```text
//! frame   := magic version length payload check
//! magic   := "GPNW"                      (4 bytes)
//! version := u16                         (PROTOCOL_VERSION)
//! length  := u32                         (payload byte count, <= MAX_PAYLOAD)
//! payload := length bytes                (one proto message)
//! check   := u64                         (FNV-1a over magic..payload)
//! ```
//!
//! The trailer is the same FNV-1a the `.gpck` checkpoint format ends
//! with ([`fnv1a`]), taken over everything before it — header included,
//! so a bit flip anywhere in the frame (even in the length field, when
//! the flipped length still lands in bounds) fails the check. Decoding is
//! total: any byte sequence produces either a payload or an
//! offset-carrying [`FrameError`], never a panic — the server feeds
//! sockets straight into [`decode_frame`], so this totality is what the
//! "malformed frames never kill the collector" guarantee rests on
//! (property-tested in `tests/proptests.rs`).

use std::fmt;
use std::io::{self, Read, Write};

use crate::telemetry::persist::fnv1a;

/// Frame magic: "GPNW" (GPu power NetWork), sibling of `.gpck`'s "GPCK".
pub const MAGIC: [u8; 4] = *b"GPNW";
/// Protocol version stamped into every frame; receivers reject mismatches
/// before touching the payload.
pub const PROTOCOL_VERSION: u16 = 1;
/// Fixed header size: magic + version + length.
pub const HEADER_LEN: usize = 10;
/// Trailer size: the FNV-1a check.
pub const TRAILER_LEN: usize = 8;
/// Payload size cap. Checkpoint interchange for a large fleet is the
/// biggest message; 64 MiB is ~30k nodes of full per-bucket accounts.
/// Anything larger is rejected at the header, before any allocation.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Why a byte sequence is not a frame. Every variant carries the byte
/// offset at which decoding stopped, so a rejected frame is debuggable
/// from the error alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    /// The input ends before the frame does: `needed` total bytes were
    /// required, only `offset` were available.
    Truncated {
        /// Bytes actually available.
        offset: usize,
        /// Total bytes the frame needs (header + payload + trailer).
        needed: usize,
    },
    /// The first four bytes are not [`MAGIC`].
    BadMagic {
        /// Offset of the first mismatching magic byte.
        offset: usize,
    },
    /// The version field does not match [`PROTOCOL_VERSION`].
    BadVersion {
        /// Offset of the version field (always 4).
        offset: usize,
        /// The version the frame claims.
        found: u16,
    },
    /// The length field exceeds [`MAX_PAYLOAD`].
    Oversized {
        /// Offset of the length field (always 6).
        offset: usize,
        /// The payload length the frame claims.
        len: u32,
    },
    /// The FNV-1a trailer does not match the frame contents.
    Checksum {
        /// Offset of the trailer.
        offset: usize,
        /// The check the frame carries.
        stored: u64,
        /// The check the bytes actually hash to.
        computed: u64,
    },
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated { offset, needed } => {
                write!(f, "truncated frame: {offset} byte(s), {needed} needed")
            }
            FrameError::BadMagic { offset } => {
                write!(f, "bad frame magic at byte {offset} (want \"GPNW\")")
            }
            FrameError::BadVersion { offset, found } => write!(
                f,
                "unsupported protocol version {found} at byte {offset} \
                 (this build speaks {PROTOCOL_VERSION})"
            ),
            FrameError::Oversized { offset, len } => write!(
                f,
                "oversized frame at byte {offset}: {len} byte payload exceeds {MAX_PAYLOAD}"
            ),
            FrameError::Checksum { offset, stored, computed } => write!(
                f,
                "frame checksum mismatch at byte {offset}: stored {stored:#018x}, \
                 computed {computed:#018x}"
            ),
        }
    }
}

impl std::error::Error for FrameError {}

/// Wrap `payload` into one wire frame.
///
/// # Panics
///
/// If `payload` exceeds [`MAX_PAYLOAD`] — proto messages are built by
/// this crate and never approach the cap; the cap guards the *decoder*
/// against adversarial length fields.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() <= MAX_PAYLOAD as usize, "frame payload over MAX_PAYLOAD");
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    let check = fnv1a(&out);
    out.extend_from_slice(&check.to_le_bytes());
    out
}

/// Validate a fixed-size header, returning the payload length it
/// declares. Shared by [`decode_frame`] and the socket read path, so a
/// streaming reader rejects garbage before allocating the payload.
pub fn parse_header(header: &[u8; HEADER_LEN]) -> Result<u32, FrameError> {
    for (i, (&got, &want)) in header.iter().zip(MAGIC.iter()).enumerate() {
        if got != want {
            return Err(FrameError::BadMagic { offset: i });
        }
    }
    let version = u16::from_le_bytes([header[4], header[5]]);
    if version != PROTOCOL_VERSION {
        return Err(FrameError::BadVersion { offset: 4, found: version });
    }
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]);
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversized { offset: 6, len });
    }
    Ok(len)
}

/// Decode one frame from the head of `bytes`: the payload slice and the
/// total bytes the frame spans (so a buffer of back-to-back frames can be
/// walked). Total over arbitrary input — see the module docs.
pub fn decode_frame(bytes: &[u8]) -> Result<(&[u8], usize), FrameError> {
    if bytes.len() < HEADER_LEN {
        return Err(FrameError::Truncated { offset: bytes.len(), needed: HEADER_LEN });
    }
    let mut header = [0u8; HEADER_LEN];
    header.copy_from_slice(&bytes[..HEADER_LEN]);
    let len = parse_header(&header)? as usize;
    let total = HEADER_LEN + len + TRAILER_LEN;
    if bytes.len() < total {
        return Err(FrameError::Truncated { offset: bytes.len(), needed: total });
    }
    let body_end = HEADER_LEN + len;
    let stored = u64::from_le_bytes(bytes[body_end..total].try_into().expect("trailer is 8 bytes"));
    let computed = fnv1a(&bytes[..body_end]);
    if stored != computed {
        return Err(FrameError::Checksum { offset: body_end, stored, computed });
    }
    Ok((&bytes[HEADER_LEN..body_end], total))
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    w.write_all(&encode_frame(payload))
}

/// Read one full frame from a stream, validating as it goes; the header
/// is parsed before the payload is allocated, so an adversarial length
/// field costs nothing. Frame violations surface as
/// [`io::ErrorKind::InvalidData`] carrying the [`FrameError`] text.
pub fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let len =
        parse_header(&header).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))? as usize;
    let mut buf = vec![0u8; HEADER_LEN + len + TRAILER_LEN];
    buf[..HEADER_LEN].copy_from_slice(&header);
    r.read_exact(&mut buf[HEADER_LEN..])?;
    match decode_frame(&buf) {
        Ok((payload, _)) => Ok(payload.to_vec()),
        Err(e) => Err(io::Error::new(io::ErrorKind::InvalidData, e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_span() {
        let payload = b"federate all the collectors";
        let frame = encode_frame(payload);
        assert_eq!(frame.len(), HEADER_LEN + payload.len() + TRAILER_LEN);
        let (got, span) = decode_frame(&frame).unwrap();
        assert_eq!(got, payload);
        assert_eq!(span, frame.len());
        // back-to-back frames walk by span
        let mut two = frame.clone();
        two.extend_from_slice(&encode_frame(b"second"));
        let (first, span) = decode_frame(&two).unwrap();
        assert_eq!(first, payload);
        let (second, _) = decode_frame(&two[span..]).unwrap();
        assert_eq!(second, b"second");
    }

    #[test]
    fn empty_payload_is_a_valid_frame() {
        let frame = encode_frame(b"");
        let (payload, span) = decode_frame(&frame).unwrap();
        assert!(payload.is_empty());
        assert_eq!(span, HEADER_LEN + TRAILER_LEN);
    }

    #[test]
    fn header_rejections_carry_offsets() {
        let mut frame = encode_frame(b"x");
        frame[2] ^= 0xFF;
        assert_eq!(decode_frame(&frame), Err(FrameError::BadMagic { offset: 2 }));

        let mut frame = encode_frame(b"x");
        frame[4] = 0x7F;
        assert!(matches!(decode_frame(&frame), Err(FrameError::BadVersion { offset: 4, .. })));

        let mut header = [0u8; HEADER_LEN];
        header[..4].copy_from_slice(&MAGIC);
        header[4..6].copy_from_slice(&PROTOCOL_VERSION.to_le_bytes());
        header[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(parse_header(&header), Err(FrameError::Oversized { offset: 6, len: u32::MAX }));
    }

    #[test]
    fn stream_read_matches_buffer_decode() {
        let frame = encode_frame(b"over the wire");
        let mut cursor = std::io::Cursor::new(frame);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"over the wire");
    }
}
