//! Tiny shared bench harness (criterion is unavailable in this offline
//! build): warm-up + N timed iterations, reporting mean / min / max.

use std::time::Instant;

/// One bench result row.
pub struct BenchRow {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    /// optional throughput annotation
    pub note: String,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchRow {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1000.0);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    let max = times.iter().cloned().fold(f64::MIN, f64::max);
    BenchRow { name: name.to_string(), iters, mean_ms: mean, min_ms: min, max_ms: max, note: String::new() }
}

/// Print rows as an aligned table.
pub fn report(title: &str, rows: &[BenchRow]) {
    println!("\n=== {title} ===");
    println!("{:<44} {:>6} {:>12} {:>12} {:>12}  {}", "benchmark", "iters", "mean ms", "min ms", "max ms", "note");
    println!("{}", "-".repeat(110));
    for r in rows {
        println!(
            "{:<44} {:>6} {:>12.3} {:>12.3} {:>12.3}  {}",
            r.name, r.iters, r.mean_ms, r.min_ms, r.max_ms, r.note
        );
    }
}
