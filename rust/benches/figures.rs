//! End-to-end benches: one per paper table/figure (DESIGN.md §5). Each
//! bench times regenerating that figure's data with the same code the CLI
//! uses, so `cargo bench --bench figures` is both a performance gate and a
//! smoke-run of the whole evaluation.

#[path = "harness.rs"]
mod harness;
use harness::{bench, report, BenchRow};

use gpupower::coordinator::{Fleet, FleetConfig, Scheduler};
use gpupower::experiments as ex;
use gpupower::measure::GoodPracticeConfig;
use gpupower::runtime::ArtifactRuntime;
use gpupower::sim::{DriverEpoch, PowerField};

fn main() {
    let seed = 2024;
    let rt = ArtifactRuntime::load_default().ok();
    if rt.is_none() {
        eprintln!("[bench] artifacts not found; fig05 and artifact paths skipped");
    }
    let mut rows: Vec<BenchRow> = Vec::new();

    rows.push(bench("table1_catalogue", 1, 20, || {
        let t = ex::tables::table1();
        assert!(!t.rows.is_empty());
    }));
    rows.push(bench("table2_workloads", 1, 20, || {
        let t = ex::tables::table2();
        assert_eq!(t.rows.len(), 9);
    }));
    rows.push(bench("fig01_motivation", 1, 3, || {
        let r = ex::fig01_motivation::run(seed);
        assert!(!r.readings.is_empty());
    }));
    if let Some(rt) = &rt {
        rows.push(bench("fig05_calibration (PJRT fma_chain)", 1, 3, || {
            let r = ex::fig05_calibration::run(rt).unwrap();
            // loose gate: this harness measures wall time while the whole
            // bench suite loads the machine; the strict R2>0.99 check lives
            // in the (quiescent) test suite and the e2e example
            assert!(r.sweep.fit.r2 > 0.9, "r2 = {}", r.sweep.fit.r2);
        }));
    }
    rows.push(bench("fig06_update_period (4 GPUs)", 0, 3, || {
        let rs = ex::fig06_update_period::run(&["V100 PCIe", "A100 PCIe-40G"], seed);
        assert_eq!(rs.len(), 2);
    }));
    rows.push(bench("fig07_transient (4 classes)", 0, 2, || {
        let rs = ex::fig07_transient::run(seed);
        assert_eq!(rs.len(), 4);
    }));
    rows.push(bench("fig08_steady_state (7x8 levels)", 0, 2, || {
        let r = ex::fig08_steady_state::run(seed);
        assert!(r.fit.r2 > 0.99);
    }));
    rows.push(bench("fig09_gradient_offset (20 cards, 2 reps)", 0, 1, || {
        let fits = ex::fig09_gradient_offset::run(seed, 2);
        assert!(fits.len() >= 15);
    }));
    rows.push(bench("fig10_boxcar_alias", 0, 2, || {
        let (a, b) = ex::fig10_boxcar_alias::run(seed);
        assert!(b.relative_swing > a.relative_swing);
    }));
    rows.push(bench("fig11_reconstruction (artifact path)", 0, 3, || {
        let r = ex::fig11_reconstruction::run(seed, rt.as_ref());
        assert!(r.mse_pmd < 0.2);
    }));
    rows.push(bench("fig12_window_loss (3 GPUs x 64 grid)", 0, 2, || {
        let c = ex::fig12_window_loss::run(seed, rt.as_ref());
        assert_eq!(c.len(), 3);
    }));
    rows.push(bench("fig13_window_dist (3 GPUs, 2 runs/frac)", 0, 1, || {
        let rs = ex::fig13_window_dist::run(2, seed);
        assert_eq!(rs.len(), 3);
    }));
    rows.push(bench("fig14_matrix (13 gens x drivers)", 0, 1, || {
        let cells = ex::fig14_matrix::run(seed);
        assert!(cells.len() > 20);
    }));
    rows.push(bench("fig15_case1 (3 periods, 4 trials)", 0, 1, || {
        let rs = ex::fig15_case1::run(4, seed);
        assert_eq!(rs.len(), 3);
    }));
    rows.push(bench("fig16_case2 (3 periods, 4 trials)", 0, 1, || {
        let rs = ex::fig16_case2::run(4, seed);
        assert_eq!(rs.len(), 3);
    }));
    rows.push(bench("fig17_case3 (3x3 grid, 4 trials)", 0, 1, || {
        let rs = ex::fig17_case3::run(4, seed);
        assert_eq!(rs.len(), 9);
    }));
    rows.push(bench("fig18_evaluation (9 workloads x 3 cases)", 0, 1, || {
        let cfg = GoodPracticeConfig { trials: 2, min_reps: 8, min_runtime_s: 1.0, ..Default::default() };
        let o = ex::fig18_evaluation::run(&cfg, seed);
        assert_eq!(o.len(), 3);
    }));
    rows.push(bench("fig19_gh200", 0, 2, || {
        let r = ex::fig19_gh200::run(seed);
        assert!(r.acpi_max_noise_w > 100.0);
    }));
    rows.push(bench("fleet_16_gpus (coordinator)", 0, 1, || {
        let fleet = Fleet::build(FleetConfig {
            size: 16,
            models: vec!["A100".into(), "3090".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed,
        });
        let sched = Scheduler {
            concurrency: 8,
            config: GoodPracticeConfig { trials: 1, min_reps: 8, min_runtime_s: 1.0, ..Default::default() },
        };
        let (outcomes, _) = sched.run(&fleet, None);
        assert_eq!(outcomes.len(), 16);
    }));

    report("figure regeneration benches", &rows);
}
