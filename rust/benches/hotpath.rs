//! Hot-path micro-benches (the §Perf targets in DESIGN.md):
//!   L3 — trace synthesis (samples/s), prefix sums, boxcar emulation,
//!        window estimation, sensor pipeline, fleet query routing;
//!   L1/L2 — PJRT artifact execution latency (fma_chain, boxcar_emulate,
//!        window_loss_grid, energy_pipeline);
//!   L4 — the fleet scheduler campaign: streaming pipeline vs the
//!        materialise-everything baseline, with a counting allocator
//!        proving the O(chunk)-per-node allocation claim and a bitwise
//!        comparison proving identical `MeasurementOutcome`s.

#[path = "harness.rs"]
mod harness;
use harness::{bench, report, BenchRow};

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use gpupower::coordinator::{CampaignConfig, Fleet, FleetConfig, Scheduler};
use gpupower::estimator::boxcar::{estimate_window, window_loss, EstimatorConfig};
use gpupower::measure::GoodPracticeConfig;
use gpupower::runtime::ArtifactRuntime;
use gpupower::sim::sensor::run_pipeline;
use gpupower::sim::{find_model, ActivitySignal, DriverEpoch, GpuDevice, PipelineSpec, PowerField};

/// Counts every heap allocation (incl. realloc growth) on top of the
/// system allocator, so the campaign bench can report allocations per
/// node for both scheduler paths.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn main() {
    let mut rows: Vec<BenchRow> = Vec::new();
    let device = GpuDevice::new(find_model("A100 PCIe-40G").unwrap(), 0, 7);
    let act = ActivitySignal::square_wave(0.3, 0.075, 0.5, 1.0, 110);

    // --- L3 simulator hot paths ---
    let mut r = bench("synthesize 9s @10kHz (90k samples)", 1, 10, || {
        let t = device.synthesize(&act, 0.0, 9.0);
        assert_eq!(t.len(), 90_000);
    });
    r.note = format!("{:.1} Msamples/s", 0.09 / (r.mean_ms / 1000.0));
    rows.push(r);

    let truth = device.synthesize(&act, 0.0, 9.0);
    let mut r = bench("prefix_sums (90k)", 1, 50, || {
        let p = truth.prefix_sums();
        assert_eq!(p.len(), 90_000);
    });
    r.note = format!("{:.0} Msamples/s", 0.09 / (r.mean_ms / 1000.0));
    rows.push(r);

    let prefix = truth.prefix_sums();
    let ts: Vec<f64> = (0..85).map(|k| 1.0 + k as f64 * 0.1).collect();
    let obs: Vec<f64> = ts.iter().map(|&t| truth.window_mean_with(&prefix, t, 0.025)).collect();
    rows.push(bench("window_loss (85 queries)", 5, 200, || {
        let l = window_loss(&truth, &prefix, &ts, &obs, 0.02);
        assert!(l.is_finite());
    }));

    let stream = run_pipeline(&device, PipelineSpec::boxcar(100.0, 25.0), &truth, 5);
    let observed: Vec<(f64, f64)> = stream.readings.iter().map(|x| (x.t, x.watts)).collect();
    rows.push(bench("estimate_window (grid32 + NM)", 1, 10, || {
        let e = estimate_window(&truth, &observed, EstimatorConfig::default());
        assert!(e.window_s > 0.0);
    }));

    rows.push(bench("sensor pipeline boxcar (90 updates)", 1, 50, || {
        let s = run_pipeline(&device, PipelineSpec::boxcar(100.0, 25.0), &truth, 5);
        assert!(s.readings.len() > 80);
    }));

    let pmd = gpupower::pmd::Pmd::new(3);
    rows.push(bench("pmd measure 9s @5kHz", 1, 20, || {
        let m = pmd.measure(&device, &truth);
        assert_eq!(m.len(), 45_000);
    }));

    // --- L1/L2 PJRT artifact execution ---
    match ArtifactRuntime::load_default() {
        Ok(rt) => {
            let x = vec![0.5f32; rt.manifest.nsize];
            let mut r = bench("PJRT fma_chain niter=10000", 2, 10, || {
                let (_, _) = rt.fma_chain(10_000, &x).unwrap();
            });
            r.note = format!(
                "{:.2} Gflop/s (2 flops x {} x 10k iters)",
                2.0 * rt.manifest.nsize as f64 * 10_000.0 / (r.mean_ms / 1000.0) / 1e9,
                rt.manifest.nsize
            );
            rows.push(r);

            let trace: Vec<f32> = truth
                .downsample(5000.0)
                .samples
                .iter()
                .copied()
                .chain(std::iter::repeat(0.0))
                .take(rt.manifest.trace_len)
                .collect();
            let idx: Vec<i32> =
                (0..rt.manifest.nq).map(|k| (600 + k * 340).min(rt.manifest.trace_len - 1) as i32).collect();
            rows.push(bench("PJRT boxcar_emulate (45k trace)", 2, 20, || {
                let e = rt.boxcar_emulate(&trace, 125, &idx).unwrap();
                assert_eq!(e.len(), rt.manifest.nq);
            }));

            let observed: Vec<f32> = idx.iter().map(|&i| trace[i as usize]).collect();
            let windows: Vec<i32> = (1..=rt.manifest.ngrid as i32).map(|i| i * 12).collect();
            rows.push(bench("PJRT window_loss_grid (64 windows)", 2, 10, || {
                let l = rt.window_loss_grid(&trace, &observed, &idx, &windows).unwrap();
                assert_eq!(l.len(), rt.manifest.ngrid);
            }));

            let series: Vec<(f64, f64)> = (0..500).map(|i| (i as f64 * 0.02, 200.0)).collect();
            let (p, t, v) = rt.pack_series(&series).unwrap();
            rows.push(bench("PJRT energy_pipeline (1024 slots)", 2, 20, || {
                let (e, _) = rt.energy_pipeline(&p, &t, &v, 0.0, 0.0).unwrap();
                assert!(e > 0.0);
            }));
        }
        Err(e) => eprintln!("[bench] artifact benches skipped: {e}"),
    }

    // --- L4: scheduler campaign — streaming vs materialise-everything ---
    // ISSUE 1 acceptance: the streaming campaign must measure the fleet
    // with >=2x less wall-time or >=10x fewer heap allocations per node,
    // with bit-for-bit identical MeasurementOutcome values.
    {
        let nodes: usize = std::env::var("CAMPAIGN_NODES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1000);
        let fleet = Fleet::build(FleetConfig {
            size: nodes,
            models: vec!["A100".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed: 5,
        });
        let cfg =
            GoodPracticeConfig { trials: 1, min_reps: 4, min_runtime_s: 0.5, ..Default::default() };
        let sched = Scheduler { concurrency: Scheduler::default().concurrency, config: cfg };
        let wl = &gpupower::bench::workloads::WORKLOADS[0];

        let mut base_out = None;
        let a0 = allocs_now();
        let mut r = bench(&format!("fleet campaign {nodes} nodes, materialised"), 0, 1, || {
            base_out = Some(sched.run(&fleet, Some(wl)));
        });
        let base_allocs = allocs_now() - a0;
        let base_ms = r.mean_ms;
        r.note = format!("{:.1} allocs/node", base_allocs as f64 / nodes as f64);
        rows.push(r);

        let mut stream_out = None;
        let a1 = allocs_now();
        let mut r = bench(&format!("fleet campaign {nodes} nodes, streaming"), 0, 1, || {
            stream_out = Some(sched.run_campaign(&fleet, Some(wl), CampaignConfig::default()));
        });
        let stream_allocs = allocs_now() - a1;
        let stream_ms = r.mean_ms;
        r.note = format!("{:.2} allocs/node", stream_allocs as f64 / nodes as f64);
        rows.push(r);

        // identical outcomes, bit for bit
        let (base_outcomes, _) = base_out.unwrap();
        let (stream_outcomes, _) = stream_out.unwrap();
        assert_eq!(base_outcomes.len(), stream_outcomes.len());
        for (a, b) in base_outcomes.iter().zip(&stream_outcomes) {
            assert_eq!(a.node_id, b.node_id);
            assert_eq!(a.naive_pct_error.to_bits(), b.naive_pct_error.to_bits());
            assert_eq!(a.good_pct_error.to_bits(), b.good_pct_error.to_bits());
            assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
            assert_eq!(a.truth_j.to_bits(), b.truth_j.to_bits());
            assert_eq!(a.window_s.to_bits(), b.window_s.to_bits());
        }

        let alloc_ratio = base_allocs as f64 / stream_allocs.max(1) as f64;
        let speedup = base_ms / stream_ms.max(1e-9);
        println!(
            "\ncampaign ({nodes} nodes): materialised {:.0} allocs/node, {:.0} ms | streaming {:.2} allocs/node, {:.0} ms",
            base_allocs as f64 / nodes as f64,
            base_ms,
            stream_allocs as f64 / nodes as f64,
            stream_ms
        );
        println!(
            "campaign win: {alloc_ratio:.1}x fewer allocations, {speedup:.2}x wall-time, outcomes bit-for-bit identical"
        );
        assert!(
            alloc_ratio >= 10.0 || speedup >= 2.0,
            "streaming campaign must win >=10x on allocations or >=2x on wall-time \
             (got {alloc_ratio:.1}x allocs, {speedup:.2}x time)"
        );
    }

    // --- L5: telemetry service — ingest throughput + O(1) alloc/reading ---
    // ISSUE 2 acceptance: ingesting more readings must not allocate
    // proportionally — per-node costs (identification, account vectors)
    // are fixed, batch buffers are pool-recycled, and the capture runs
    // through reused scratch arenas. Two runs differing only in window
    // length isolate the marginal allocations per additional reading.
    {
        let nodes: usize = std::env::var("TELEMETRY_NODES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(24);
        let fleet = Fleet::build(FleetConfig {
            size: nodes,
            models: vec!["A100".into(), "3090".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed: 9,
        });
        let cfg_short = gpupower::telemetry::TelemetryConfig { duration_s: 30.0, ..Default::default() };
        let cfg_long = gpupower::telemetry::TelemetryConfig { duration_s: 40.0, ..Default::default() };

        let mut snap = None;
        let a0 = allocs_now();
        let mut r = bench(&format!("telemetry {nodes} nodes, 30 s window"), 0, 1, || {
            snap = Some(gpupower::telemetry::run_service(&fleet, &cfg_short));
        });
        let short_allocs = allocs_now() - a0;
        let short = snap.take().unwrap();
        r.note = format!(
            "{:.2} Mreadings/s, {:.2} allocs/reading",
            short.stats.readings as f64 / (r.mean_ms / 1000.0) / 1e6,
            short_allocs as f64 / short.stats.readings.max(1) as f64
        );
        rows.push(r);

        let a1 = allocs_now();
        let mut r = bench(&format!("telemetry {nodes} nodes, 40 s window"), 0, 1, || {
            snap = Some(gpupower::telemetry::run_service(&fleet, &cfg_long));
        });
        let long_allocs = allocs_now() - a1;
        let long = snap.take().unwrap();
        r.note = format!(
            "{:.2} Mreadings/s, {:.2} allocs/reading",
            long.stats.readings as f64 / (r.mean_ms / 1000.0) / 1e6,
            long_allocs as f64 / long.stats.readings.max(1) as f64
        );
        rows.push(r);

        let extra_readings = long.stats.readings.saturating_sub(short.stats.readings);
        let extra_allocs = long_allocs.saturating_sub(short_allocs);
        let marginal = extra_allocs as f64 / extra_readings.max(1) as f64;
        println!(
            "\ntelemetry ({nodes} nodes): 30 s = {} readings / {} allocs | 40 s = {} readings / {} allocs",
            short.stats.readings, short_allocs, long.stats.readings, long_allocs
        );
        println!(
            "telemetry win: {marginal:.4} marginal allocations per additional ingested reading (O(1) amortised)"
        );
        // 10 s more window at 2 ms polling ≈ 5000 extra readings per node;
        // scale the floor with the TELEMETRY_NODES knob instead of assuming
        // the default fleet size
        assert!(
            extra_readings > 2_000 * nodes as u64,
            "longer window must ingest substantially more readings (got {extra_readings} for {nodes} nodes)"
        );
        assert!(
            marginal < 0.05,
            "ingestion must be O(1) alloc per reading: {marginal:.4} marginal allocs/reading"
        );
        assert_eq!(short.stats.nodes, nodes, "every node accounted");
    }

    // --- L6: sharded accounting — the committed BENCH trajectory ---
    // ISSUE 6 acceptance: readings/s at 1/2/4/8 accounting shards,
    // allocations per reading, and mid-ingest snapshot latency, written
    // as machine-readable JSON (BENCH_TELEMETRY_OUT) so the repo carries
    // a perf trajectory (BENCH_telemetry.json) that CI can regress
    // against. BENCH_SMOKE=1 shrinks the fleet/window for CI runners.
    {
        use gpupower::telemetry::{
            ServiceEvent, ServiceSource, TelemetryConfig, TelemetryService,
        };

        let smoke = std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
        let (default_nodes, duration_s) = if smoke { (8usize, 12.0) } else { (32usize, 30.0) };
        let nodes: usize = std::env::var("SHARD_BENCH_NODES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(default_nodes);
        let fleet = Fleet::build(FleetConfig {
            size: nodes,
            models: vec!["A100".into(), "3090".into()],
            driver: DriverEpoch::Post530,
            field: PowerField::Instant,
            seed: 6,
        });
        let shard_counts = [1usize, 2, 4, 8];
        // (shards, readings/s, allocs/reading, mid-ingest snapshot µs,
        //  back-to-back cached snapshot µs)
        let mut entries: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
        let mut reference_readings: Option<u64> = None;

        for &shards in &shard_counts {
            let cfg = TelemetryConfig { duration_s, shards, ..Default::default() };

            // mid-ingest snapshot latency: wait for the first identity
            // (ingest is ramped and accounts are non-trivial), then time
            // one live snapshot while every shard keeps ingesting, and a
            // second immediately after — the second is served by the
            // per-shard fold cache except for shards that moved between
            // the two calls, so it exposes the O(1)-per-quiet-shard path
            let handle = TelemetryService::start(&fleet, &cfg, &ServiceSource::Sim);
            let events = handle.subscribe();
            let mut snap_us = 0.0f64;
            let mut snap_cached_us = 0.0f64;
            for ev in &events {
                if matches!(ev, ServiceEvent::NodeIdentified { .. }) {
                    let t = std::time::Instant::now();
                    let live = handle.snapshot();
                    snap_us = t.elapsed().as_secs_f64() * 1e6;
                    let t = std::time::Instant::now();
                    let cached = handle.snapshot();
                    snap_cached_us = t.elapsed().as_secs_f64() * 1e6;
                    assert!(live.accounts.nodes.len() <= nodes);
                    assert!(cached.accounts.nodes.len() <= nodes);
                    break;
                }
            }
            drop(events);
            handle.join();

            // throughput + allocations over a full drain
            let a0 = allocs_now();
            let mut out = None;
            let mut r = bench(&format!("telemetry {nodes} nodes, {shards} shard(s)"), 0, 1, || {
                out = Some(gpupower::telemetry::run_service_with(
                    &fleet,
                    &cfg,
                    &ServiceSource::Sim,
                ));
            });
            let run_allocs = allocs_now() - a0;
            let snap = out.unwrap();
            match reference_readings {
                None => reference_readings = Some(snap.stats.readings),
                Some(want) => assert_eq!(
                    snap.stats.readings, want,
                    "{shards} shards must ingest the identical reading count"
                ),
            }
            let readings_per_s = snap.stats.readings as f64 / (r.mean_ms / 1000.0);
            let allocs_per_reading = run_allocs as f64 / snap.stats.readings.max(1) as f64;
            r.note = format!(
                "{:.2} Mreadings/s, {allocs_per_reading:.3} allocs/reading, snapshot {snap_us:.0} µs ({snap_cached_us:.0} µs cached)",
                readings_per_s / 1e6
            );
            rows.push(r);
            entries.push((shards, readings_per_s, allocs_per_reading, snap_us, snap_cached_us));
        }

        // instrumentation overhead gate (ISSUE 7): the same 1-shard run
        // with the metrics registry hot vs cold, reps interleaved so
        // machine drift hits both arms equally, best-of-each compared —
        // the observability layer must cost < 2 %
        let mut best_on = f64::INFINITY;
        let mut best_off = f64::INFINITY;
        for _ in 0..3 {
            for &metrics in &[false, true] {
                let cfg =
                    TelemetryConfig { duration_s, shards: 1, metrics, ..Default::default() };
                let t = std::time::Instant::now();
                let snap =
                    gpupower::telemetry::run_service_with(&fleet, &cfg, &ServiceSource::Sim);
                let dt = t.elapsed().as_secs_f64();
                assert_eq!(
                    Some(snap.stats.readings),
                    reference_readings,
                    "metrics={metrics} must not change the ingested reading count"
                );
                if metrics {
                    best_on = best_on.min(dt);
                } else {
                    best_off = best_off.min(dt);
                }
            }
        }
        let overhead = best_on / best_off;
        println!(
            "\ntelemetry instrumentation overhead: {overhead:.4}x \
             (best-of-3: metrics on {:.1} ms vs off {:.1} ms; gate < 1.02x)",
            best_on * 1e3,
            best_off * 1e3
        );
        assert!(
            overhead < 1.02,
            "metrics instrumentation must stay under the 2% budget: {overhead:.4}x"
        );

        let base = entries[0].1;
        let snap_scaling = entries.last().map(|e| e.3 / entries[0].3.max(1e-9)).unwrap_or(1.0);
        println!("\ntelemetry shard trajectory ({nodes} nodes, {duration_s:.0} s window):");
        for &(shards, rps, apr, us, cus) in &entries {
            println!(
                "  {shards} shard(s): {:.2} Mreadings/s ({:.2}x), {apr:.3} allocs/reading, snapshot {us:.0} µs ({cus:.0} µs cached)",
                rps / 1e6,
                rps / base
            );
        }
        println!(
            "  snapshot scaling {}-shard / 1-shard: {snap_scaling:.2}x (flat-in-shards gate lives in check_bench.py)",
            entries.last().map(|e| e.0).unwrap_or(1)
        );

        // machine-readable trajectory for BENCH_telemetry.json
        if let Ok(path) = std::env::var("BENCH_TELEMETRY_OUT") {
            let mut json = String::new();
            json.push_str("{\n");
            json.push_str("  \"schema\": \"bench_telemetry/v3\",\n");
            json.push_str(&format!(
                "  \"mode\": \"{}\",\n",
                if smoke { "smoke" } else { "full" }
            ));
            json.push_str(&format!("  \"nodes\": {nodes},\n"));
            json.push_str(&format!("  \"duration_s\": {duration_s:.1},\n"));
            json.push_str(&format!("  \"instrumented_overhead\": {overhead:.4},\n"));
            json.push_str(&format!("  \"snapshot_scaling\": {snap_scaling:.4},\n"));
            json.push_str("  \"shards\": {\n");
            for (i, &(shards, rps, apr, us, cus)) in entries.iter().enumerate() {
                json.push_str(&format!(
                    "    \"{shards}\": {{\"readings_per_s\": {:.0}, \"allocs_per_reading\": {apr:.4}, \"snapshot_latency_us\": {us:.1}, \"snapshot_cached_us\": {cus:.1}}}{}\n",
                    rps,
                    if i + 1 < entries.len() { "," } else { "" }
                ));
            }
            json.push_str("  }\n}\n");
            std::fs::write(&path, json).expect("write BENCH_TELEMETRY_OUT");
            println!("telemetry trajectory written to {path}");
        }
    }

    report("hot-path benches", &rows);
}
