"""Pure-jnp oracles for the Pallas kernels. pytest asserts kernel == ref."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def fma_chain_ref(x, niter):
    """Reference FMA chain: same serial semantics, no Pallas."""

    def body(_, v):
        v = v * 2.0 + 2.0
        v = v / 2.0 - 1.0
        return v

    return lax.fori_loop(0, jnp.asarray(niter).reshape(()).astype(jnp.int32), body, x)


def sliding_boxcar_ref(x, window):
    """Reference trailing boxcar; O(n*w) direct form, trusted by inspection."""
    x = jnp.asarray(x, jnp.float32)
    w = int(window)
    n = x.shape[0]
    out = []
    for i in range(n):
        lo = max(0, i - w + 1)
        out.append(x[lo : i + 1].mean())
    return jnp.stack(out)


def sliding_boxcar_ref_fast(x, window):
    """Vectorised reference (cumsum form) for large-n property tests."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(window).reshape(()).astype(jnp.int32)
    n = x.shape[0]
    csum = jnp.cumsum(x)
    idx = jnp.arange(n)
    lo = jnp.maximum(idx - w, -1)
    start = jnp.where(lo < 0, 0.0, csum[jnp.maximum(lo, 0)])
    count = (idx - lo).astype(jnp.float32)
    return (csum - start) / jnp.maximum(count, 1.0)
