"""L1 Pallas kernel: the paper's benchmark-load FMA chain (Listing 1), re-thought for TPU.

The CUDA original runs one thread per element and a serially-dependent chain

    x = x * 2 + 2
    x = x / 2 - 1        (net identity -- but only if actually executed)

for ``niter`` iterations, with ``nblocks = SM_count * fraction`` controlling the
power amplitude and ``niter`` controlling the duration (linear, Fig. 5).

TPU adaptation (DESIGN.md section "Hardware-Adaptation"):
  * the element vector is tiled into VMEM blocks via BlockSpec (the HBM<->VMEM
    schedule CUDA expressed with threadblocks);
  * the chain runs as a ``lax.fori_loop`` *inside* the kernel, so the 2*niter
    VPU ops are serially data-dependent and cannot be algebraically collapsed;
  * ``niter`` arrives as a runtime scalar so a single AOT artifact covers every
    duration (the Rust coordinator sweeps it for the Fig. 5 calibration).

``interpret=True`` always: on this CPU PJRT stack a real TPU lowering would emit
a Mosaic custom-call the CPU plugin cannot execute. Correctness is pinned by
``ref.py`` (pure jnp) via pytest + hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default artifact geometry. 16384 f32 = 64 KiB per operand; block 2048 f32 =
# 8 KiB, an 8-step grid -- comfortably within a single TPU core's ~16 MiB VMEM
# with double buffering, and fast enough under interpret mode.
NSIZE = 16384
BLOCK = 2048


def _kernel(niter_ref, x_ref, o_ref):
    """One VMEM block of the FMA chain."""
    niter = niter_ref[0]

    def body(_, v):
        v = v * 2.0 + 2.0
        v = v / 2.0 - 1.0
        return v

    o_ref[...] = lax.fori_loop(0, niter, body, x_ref[...])


def fma_chain(x: jax.Array, niter: jax.Array, *, block: int = BLOCK) -> jax.Array:
    """Run the FMA chain over ``x`` for ``niter`` iterations.

    Args:
      x: f32[n] work vector (n divisible by ``block``).
      niter: i32[1] chain length (runtime-dynamic).
      block: VMEM block size in elements.

    Returns:
      f32[n]; numerically ~equal to ``x`` (the chain is an identity when
      executed), which is what makes it a pure *power/duration* load.
    """
    n = x.shape[0]
    if n % block:
        raise ValueError(f"n={n} not divisible by block={block}")
    grid = n // block
    return pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),        # niter: broadcast scalar
            pl.BlockSpec((block,), lambda i: (i,)),    # x: one VMEM tile per step
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(niter, x)
