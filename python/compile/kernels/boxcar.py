"""L1 Pallas kernel: sliding boxcar average over a high-rate power trace.

The sensor pipeline the paper reverse-engineers is exactly this operator: the
reported power at time ``t`` is the mean of the true power over the trailing
``window`` samples. This kernel produces the *dense* boxcar-filtered trace used
by the Fig. 10/11 emulations; the L2 graph then gathers it at the smi query
timestamps.

Single-block kernel: a 9 s trace at 5 kHz is 45 000 f32 = 176 KiB, far below
VMEM capacity, so the whole trace is staged at once and the prefix-sum runs
in-core (O(n), not O(n*w) convolution -- see DESIGN.md section 7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TRACE_LEN = 45_000  # 9 s at 5 kHz, the paper's Fig. 11 capture length


def _kernel(window_ref, x_ref, o_ref):
    x = x_ref[...]
    w = window_ref[0]
    n = x.shape[0]
    # associative_scan, NOT jnp.cumsum: on the CPU backend cumsum lowers to
    # a ReduceWindow that executes in O(n^2) (≈400 ms for 45 k samples);
    # the scan is O(n log n) (measured ~100x faster; EXPERIMENTS.md §Perf)
    csum = jax.lax.associative_scan(jnp.add, x)
    idx = jnp.arange(n)
    lo = idx - w  # exclusive start of the trailing window
    lo_clamped = jnp.maximum(lo, -1)
    start_sum = jnp.where(lo_clamped < 0, 0.0, csum[jnp.maximum(lo_clamped, 0)])
    count = (idx - lo_clamped).astype(jnp.float32)
    o_ref[...] = (csum - start_sum) / jnp.maximum(count, 1.0)


def sliding_boxcar(x: jax.Array, window: jax.Array) -> jax.Array:
    """Trailing-window moving average.

    Args:
      x: f32[n] trace.
      window: i32[1] window length in samples (>=1; clamped at trace start).

    Returns:
      f32[n]; ``out[i] = mean(x[max(0, i-w+1) : i+1])``.
    """
    n = x.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(window, x)
