"""AOT: lower every L2 entry point to HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.boxcar import TRACE_LEN
from .kernels.fma_chain import BLOCK, NSIZE


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


ENTRIES = {
    "fma_chain": (model.fma_chain_entry, (i32(1), f32(NSIZE))),
    "boxcar_emulate": (
        model.boxcar_emulate_entry,
        (f32(TRACE_LEN), i32(1), i32(model.NQ)),
    ),
    "window_loss_grid": (
        model.window_loss_grid_entry,
        (f32(TRACE_LEN), f32(model.NQ), i32(model.NQ), i32(model.NGRID)),
    ),
    "energy_pipeline": (
        model.energy_pipeline_entry,
        (f32(model.NP), f32(model.NP), f32(model.NP), f32(1), f32(1)),
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single entry point")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = [args.only] if args.only else list(ENTRIES)
    for name in names:
        fn, specs = ENTRIES[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "nsize": NSIZE,
        "block": BLOCK,
        "trace_len": TRACE_LEN,
        "nq": model.NQ,
        "ngrid": model.NGRID,
        "np": model.NP,
        "entries": {
            name: {
                "inputs": [
                    {"dtype": str(s.dtype), "shape": list(s.shape)} for s in specs
                ]
            }
            for name, (_, specs) in ENTRIES.items()
        },
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
