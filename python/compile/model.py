"""L2: JAX compute graphs for the gpupower measurement stack.

Four AOT entry points (see DESIGN.md section 3), each lowered once by aot.py to
an HLO-text artifact that the Rust coordinator loads via PJRT. All shapes are
static; runtime-variable quantities (chain length, window size, sample indices,
validity masks) are runtime *inputs*, so one artifact serves every experiment.

Python never runs on the request path: these functions exist only to be lowered.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.boxcar import TRACE_LEN, sliding_boxcar
from .kernels.fma_chain import NSIZE, fma_chain

# Static artifact geometry (mirrored into artifacts/manifest.json for Rust).
NQ = 128      # max nvidia-smi query samples per capture (9 s / 100 ms = 90, padded)
NGRID = 64    # candidate averaging-window grid for the Fig. 12 loss scan
NP = 1024     # max power samples fed to the energy pipeline


def fma_chain_entry(niter: jax.Array, x: jax.Array):
    """The benchmark-load compute kernel (paper Listing 1).

    niter: i32[1]; x: f32[NSIZE]. Duration of execution is linear in niter
    (Fig. 5); the Rust coordinator times this artifact to calibrate the
    square-wave high state.
    """
    return (fma_chain(x, niter),)


def boxcar_emulate_entry(trace: jax.Array, window: jax.Array, sample_idx: jax.Array):
    """Emulate an nvidia-smi power series from a 5 kHz ground-truth trace.

    trace: f32[TRACE_LEN]; window: i32[1] (samples); sample_idx: i32[NQ]
    (indices of the smi update instants in the trace).
    Returns f32[NQ]: mean of the trailing ``window`` samples at each instant --
    the paper's section 4.3 emulation model.
    """
    dense = sliding_boxcar(trace, window)
    return (dense[sample_idx],)


def _normalise(v):
    """Z-score; the paper compares only the *shape* of original vs emulated."""
    mu = jnp.mean(v)
    sd = jnp.std(v) + 1e-9
    return (v - mu) / sd


def _emulate_cumsum(trace, window, sample_idx):
    """Cumsum-form boxcar gather (O(1) per query), jnp-only so it vmaps cheaply.

    Prefix sums via associative_scan: `jnp.cumsum` lowers to a quadratic
    ReduceWindow on the CPU backend (see EXPERIMENTS.md §Perf).
    """
    csum = jax.lax.associative_scan(jnp.add, trace)
    lo = jnp.maximum(sample_idx - window, -1)
    start = jnp.where(lo < 0, 0.0, csum[jnp.maximum(lo, 0)])
    count = (sample_idx - lo).astype(jnp.float32)
    return (csum[sample_idx] - start) / jnp.maximum(count, 1.0)


def window_loss_grid_entry(
    trace: jax.Array, observed: jax.Array, sample_idx: jax.Array, windows: jax.Array
):
    """MSE loss between observed smi data and emulations for NGRID windows.

    trace: f32[TRACE_LEN]; observed: f32[NQ]; sample_idx: i32[NQ];
    windows: i32[NGRID]. Returns f32[NGRID] of shape-normalised MSEs -- the
    Fig. 12 loss curve. The Rust Nelder-Mead refines around the grid minimum.
    """
    obs_n = _normalise(observed)
    # hoist the O(n log n) prefix scan out of the vmap: it is window-
    # independent, so it must run once per grid call, not NGRID times
    csum = jax.lax.associative_scan(jnp.add, trace)

    def loss(w):
        lo = jnp.maximum(sample_idx - w, -1)
        start = jnp.where(lo < 0, 0.0, csum[jnp.maximum(lo, 0)])
        count = (sample_idx - lo).astype(jnp.float32)
        em = _normalise((csum[sample_idx] - start) / jnp.maximum(count, 1.0))
        return jnp.mean((em - obs_n) ** 2)

    return (jax.vmap(loss)(windows),)


def energy_pipeline_entry(
    power: jax.Array,
    ts: jax.Array,
    valid: jax.Array,
    shift: jax.Array,
    discard_until: jax.Array,
):
    """Good-practice energy post-processing (paper section 5.1 corrections).

    power: f32[NP] watts; ts: f32[NP] seconds; valid: f32[NP] 0/1 mask
    (padding); shift: f32[1] seconds to move readings *earlier* (boxcar
    latency compensation); discard_until: f32[1] seconds (rise-time discard).

    Returns (energy_joules f32[], effective_duration f32[]). Trapezoidal
    integration over segments whose both endpoints are valid and past the
    discard horizon.
    """
    t = ts - shift[0]
    keep = valid * (t >= discard_until[0]).astype(jnp.float32)
    seg_keep = keep[1:] * keep[:-1]
    dt = (t[1:] - t[:-1]) * seg_keep
    mid = 0.5 * (power[1:] + power[:-1])
    energy = jnp.sum(mid * dt)
    duration = jnp.sum(dt)
    return (energy, duration)
