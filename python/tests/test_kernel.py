"""Pallas kernel vs pure-jnp oracle -- the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.boxcar import sliding_boxcar
from compile.kernels.fma_chain import BLOCK, NSIZE, fma_chain


def _fma(x, niter, block=BLOCK):
    return np.asarray(fma_chain(jnp.asarray(x, jnp.float32), jnp.array([niter], jnp.int32), block=block))


class TestFmaChain:
    def test_identity_property(self):
        """(x*2+2)/2-1 == x each iteration: the chain is a pure duration load."""
        x = np.linspace(-10, 10, NSIZE).astype(np.float32)
        out = _fma(x, 100)
        np.testing.assert_allclose(out, x, rtol=1e-5, atol=1e-5)

    def test_zero_iters_is_passthrough(self):
        x = np.random.default_rng(0).normal(size=NSIZE).astype(np.float32)
        np.testing.assert_array_equal(_fma(x, 0), x)

    def test_matches_ref(self):
        x = np.random.default_rng(1).normal(size=NSIZE).astype(np.float32)
        want = np.asarray(ref.fma_chain_ref(jnp.asarray(x), 17))
        np.testing.assert_allclose(_fma(x, 17), want, rtol=1e-6)

    def test_bad_block_raises(self):
        with pytest.raises(ValueError):
            fma_chain(jnp.zeros(100, jnp.float32), jnp.array([1], jnp.int32), block=64)

    @settings(max_examples=20, deadline=None)
    @given(
        niter=st.integers(min_value=0, max_value=64),
        log2n=st.integers(min_value=7, max_value=13),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_sweep_shapes(self, niter, log2n, seed):
        """Hypothesis sweep over sizes/iteration counts vs the ref oracle."""
        n = 2**log2n
        block = min(n, 512)
        x = np.random.default_rng(seed).uniform(-4, 4, size=n).astype(np.float32)
        got = _fma(x, niter, block=block)
        want = np.asarray(ref.fma_chain_ref(jnp.asarray(x), niter))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(niter=st.integers(min_value=0, max_value=32))
    def test_property_identity_any_niter(self, niter):
        x = np.linspace(0.5, 3.0, 1024).astype(np.float32)
        np.testing.assert_allclose(_fma(x, niter, block=256), x, rtol=1e-5, atol=1e-5)


class TestSlidingBoxcar:
    def _run(self, x, w):
        return np.asarray(sliding_boxcar(jnp.asarray(x, jnp.float32), jnp.array([w], jnp.int32)))

    def test_window_one_is_identity(self):
        # cumsum-difference form: identity up to fp cancellation error
        x = np.random.default_rng(2).normal(size=333).astype(np.float32)
        np.testing.assert_allclose(self._run(x, 1), x, rtol=1e-4, atol=2e-5)

    def test_matches_direct_ref(self):
        x = np.random.default_rng(3).normal(size=200).astype(np.float32)
        want = np.asarray(ref.sliding_boxcar_ref(x, 17))
        np.testing.assert_allclose(self._run(x, 17), want, rtol=1e-4, atol=1e-5)

    def test_constant_trace_invariant(self):
        """Boxcar of a constant is the constant, for any window."""
        x = np.full(500, 123.25, np.float32)
        for w in (1, 7, 100, 500, 1000):
            np.testing.assert_allclose(self._run(x, w), x, rtol=1e-5)

    def test_full_window_is_running_mean(self):
        x = np.arange(100, dtype=np.float32)
        got = self._run(x, 1000)  # window longer than trace -> running mean
        want = np.cumsum(x) / np.arange(1, 101)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_square_wave_attenuation(self):
        """A window equal to the square-wave period flattens it to the mean --
        the paper's Fig. 10 RTX 3090 observation."""
        period = 100
        x = np.tile(np.concatenate([np.full(50, 200.0), np.full(50, 80.0)]), 20).astype(np.float32)
        out = self._run(x, period)
        steady = out[2 * period:]
        assert np.all(np.abs(steady - 140.0) < 1.5)

    def test_fractional_window_preserves_swing(self):
        """A window = period/4 keeps high/low excursions -- Fig. 10 A100."""
        x = np.tile(np.concatenate([np.full(50, 200.0), np.full(50, 80.0)]), 20).astype(np.float32)
        out = self._run(x, 25)
        steady = out[200:]
        assert steady.max() > 195.0 and steady.min() < 85.0

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=2, max_value=400),
        w=st.integers(min_value=1, max_value=500),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_vs_fast_ref(self, n, w, seed):
        # associative_scan sums in tree order vs the ref's sequential
        # cumsum; with f32 and values up to 400 the prefix differences can
        # reach ~1e-2 after cancellation, hence the tolerance
        x = np.random.default_rng(seed).uniform(0, 400, size=n).astype(np.float32)
        want = np.asarray(ref.sliding_boxcar_ref_fast(jnp.asarray(x), w))
        np.testing.assert_allclose(self._run(x, w), want, rtol=5e-4, atol=5e-2)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(min_value=10, max_value=300),
        w=st.integers(min_value=1, max_value=300),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_property_bounds(self, n, w, seed):
        """Boxcar output is bounded by the input range (convexity), up to
        f32 prefix-cancellation error (~1e-4 relative)."""
        x = np.random.default_rng(seed).uniform(50, 700, size=n).astype(np.float32)
        out = self._run(x, w)
        tol = 1e-6 * float(x.sum()) + 1e-2
        assert out.min() >= x.min() - tol
        assert out.max() <= x.max() + tol
