"""L2 graph tests: emulation, loss grid, energy pipeline semantics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels.boxcar import TRACE_LEN

RNG = np.random.default_rng(42)


def _square_trace(period_samples, hi=300.0, lo=60.0, n=TRACE_LEN, phase=0, noise=0.0, seed=7):
    i = (np.arange(n) + phase) % period_samples
    t = np.where(i < period_samples // 2, hi, lo).astype(np.float32)
    if noise:
        t = t + np.random.default_rng(seed).normal(0, noise, n).astype(np.float32)
    return t


def _smi_idx(update_samples, n=TRACE_LEN, nq=model.NQ, start=0):
    idx = start + np.arange(1, nq + 1) * update_samples
    return np.clip(idx, 0, n - 1).astype(np.int32)


class TestBoxcarEmulate:
    def test_flat_trace(self):
        trace = jnp.full((TRACE_LEN,), 150.0, jnp.float32)
        idx = jnp.asarray(_smi_idx(500))
        (out,) = model.boxcar_emulate_entry(trace, jnp.array([125], jnp.int32), idx)
        np.testing.assert_allclose(np.asarray(out), 150.0, rtol=1e-5)

    def test_window_fraction_preserves_swing(self):
        """25 ms window / 100 ms period (A100): emulated values reach hi and lo."""
        trace = jnp.asarray(_square_trace(500))  # 100 ms at 5 kHz
        idx = jnp.asarray(_smi_idx(500))
        # 25 ms = 125 samples; sample instants at multiples of the period see
        # the trailing low half-cycle.
        (out,) = model.boxcar_emulate_entry(trace, jnp.array([125], jnp.int32), idx)
        out = np.asarray(out)
        assert out.min() < 70.0  # trailing window fully in the low state

    def test_window_equal_period_flattens(self):
        trace = jnp.asarray(_square_trace(500))
        idx = jnp.asarray(_smi_idx(500))
        (out,) = model.boxcar_emulate_entry(trace, jnp.array([500], jnp.int32), idx)
        np.testing.assert_allclose(np.asarray(out), 180.0, atol=2.0)


class TestWindowLossGrid:
    def _observed(self, trace, true_window, idx):
        (obs,) = model.boxcar_emulate_entry(
            jnp.asarray(trace), jnp.array([true_window], jnp.int32), jnp.asarray(idx)
        )
        return obs

    def test_minimum_at_true_window(self):
        """The loss grid recovers the ground-truth averaging window -- the core
        of the paper's section 4.3 estimator (Fig. 12)."""
        # aliased load: period = 3/4 of the 100 ms update period, plus sensor
        # noise (pure periodic squares are shape-degenerate across windows)
        trace = _square_trace(375, noise=2.0)
        idx = _smi_idx(500, start=137)
        obs = self._observed(trace, 125, idx)
        windows = jnp.asarray((np.arange(model.NGRID) + 1) * 5, jnp.int32)  # 1..64 ms
        (losses,) = model.window_loss_grid_entry(
            jnp.asarray(trace), obs, jnp.asarray(idx), windows
        )
        best = int(np.asarray(windows)[np.argmin(np.asarray(losses))])
        assert abs(best - 125) <= 10  # within two grid steps of 25 ms

    @settings(max_examples=8, deadline=None)
    @given(true_w=st.sampled_from([50, 125, 250]), period=st.sampled_from([333, 375, 400, 625]))
    def test_property_recovery(self, true_w, period):
        trace = _square_trace(period, noise=2.0, seed=period)
        idx = _smi_idx(500, start=211)
        obs = self._observed(trace, true_w, idx)
        windows = jnp.asarray((np.arange(model.NGRID) + 1) * 5, jnp.int32)
        (losses,) = model.window_loss_grid_entry(
            jnp.asarray(trace), obs, jnp.asarray(idx), windows
        )
        best = int(np.asarray(windows)[np.argmin(np.asarray(losses))])
        assert abs(best - true_w) <= 15


class TestEnergyPipeline:
    def _run(self, power, ts, valid=None, shift=0.0, discard=0.0):
        n = model.NP
        p = np.zeros(n, np.float32)
        t = np.zeros(n, np.float32)
        v = np.zeros(n, np.float32)
        p[: len(power)] = power
        t[: len(ts)] = ts
        v[: len(power)] = 1.0 if valid is None else valid
        e, d = model.energy_pipeline_entry(
            jnp.asarray(p), jnp.asarray(t), jnp.asarray(v),
            jnp.array([shift], jnp.float32), jnp.array([discard], jnp.float32),
        )
        return float(e), float(d)

    def test_constant_power(self):
        ts = np.arange(100) * 0.1
        e, d = self._run(np.full(100, 200.0), ts)
        assert abs(e - 200.0 * 9.9) < 1e-2
        assert abs(d - 9.9) < 1e-4

    def test_discard_rise_time(self):
        ts = np.arange(100) * 0.1
        e, _ = self._run(np.full(100, 200.0), ts, discard=5.0)
        # only segments fully past 5.0 s contribute: 4.9 s worth
        assert abs(e - 200.0 * 4.9) < 1e-2

    def test_shift_moves_discard_boundary(self):
        ts = np.arange(100) * 0.1
        e_noshift, _ = self._run(np.full(100, 100.0), ts, discard=5.0)
        e_shift, _ = self._run(np.full(100, 100.0), ts, shift=1.0, discard=5.0)
        assert e_shift < e_noshift  # shifting earlier removes ~1 s more

    def test_padding_excluded(self):
        ts = np.arange(10) * 1.0
        e, d = self._run(np.full(10, 50.0), ts)
        assert abs(e - 50.0 * 9.0) < 1e-3
        assert abs(d - 9.0) < 1e-5

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 512))
    def test_property_matches_trapz(self, seed, n):
        r = np.random.default_rng(seed)
        p = r.uniform(50, 400, n).astype(np.float32)
        ts = np.cumsum(r.uniform(0.01, 0.2, n)).astype(np.float32)
        e, d = self._run(p, ts)
        np.testing.assert_allclose(e, np.trapezoid(p, ts), rtol=1e-3)
        np.testing.assert_allclose(d, ts[-1] - ts[0], rtol=1e-4)


class TestAotLowering:
    def test_all_entries_lower(self):
        """Every artifact entry point lowers to HLO text without error."""
        from compile.aot import ENTRIES, to_hlo_text

        for name, (fn, specs) in ENTRIES.items():
            text = to_hlo_text(jax.jit(fn).lower(*specs))
            assert "ENTRY" in text, name
            assert len(text) > 500, name
